"""End-to-end FL training driver: GradESTC vs FedAvg on the synthetic LM task.

Trains a small transformer federatedly for a few hundred rounds (default 60
for CPU friendliness; pass --rounds 300 for the full run), printing loss,
accuracy, and exact cumulative uplink for both methods, then the savings.

Run:  PYTHONPATH=src python examples/train_federated.py [--rounds N] [--alpha 0.5]
"""

import argparse

from repro.core.metrics import bytes_h
from repro.fl import FLConfig, run_fl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=None,
                    help="Dirichlet non-IID parameter (paper: 0.5 / 0.1)")
    ap.add_argument("--local-steps", type=int, default=2)
    args = ap.parse_args()

    results = {}
    for method in ("fedavg", "gradestc"):
        print(f"\n=== {method} ===")
        cfg = FLConfig(
            method=method, rounds=args.rounds, n_clients=args.clients,
            local_steps=args.local_steps, alpha=args.alpha,
            batch=16, seq=64, eval_every=max(1, args.rounds // 10),
        )
        res = run_fl(cfg, progress=lambda r, info: print(
            f"  round {r:4d}  loss={info['loss']:.4f}  acc={info['acc']:.4f}  "
            f"uplink={bytes_h(info['uplink'])}", flush=True))
        results[method] = res

    fa, ge_ = results["fedavg"], results["gradestc"]
    print("\n=== summary ===")
    print(f"final loss : fedavg {fa.eval_loss[-1]:.4f}   gradestc {ge_.eval_loss[-1]:.4f}")
    print(f"final acc  : fedavg {fa.eval_acc[-1]:.4f}   gradestc {ge_.eval_acc[-1]:.4f}")
    print(f"uplink     : fedavg {bytes_h(fa.ledger.uplink_total)}   "
          f"gradestc {bytes_h(ge_.ledger.uplink_total)}")
    saving = 1 - ge_.ledger.uplink_total / fa.ledger.uplink_total
    print(f"uplink saved by GradESTC: {saving:.1%}  "
          f"(paper reports 86.7% vs FedAvg on CIFAR-10 IID at full scale)")


if __name__ == "__main__":
    main()
