"""Batched serving example: prefill + decode with KV caches on a reduced
architecture (pick any of the 10 assigned archs).

Run:  PYTHONPATH=src python examples/serve_batched.py --arch gemma3-1b --steps 24
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main())
