"""Reproduce the paper's Figure 1 empirical analysis: temporal correlation
of one client's gradients, per parameter group.

Prints the mean adjacent-round cosine similarity per group, ordered by
parameter count -- demonstrating the paper's two observations:
  1. adjacent-round gradients are strongly correlated;
  2. the correlation is strongest in parameter-dominant groups.

Run:  PYTHONPATH=src python examples/temporal_correlation.py [--rounds 15]
"""

import argparse
import sys

sys.path.insert(0, ".")   # for benchmarks import when run from repo root

from benchmarks.fig1_temporal import adjacent_summary, run  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    args = ap.parse_args()

    rows = run(rounds=args.rounds)
    summary = adjacent_summary(rows)
    print(f"{'group':32s} {'params':>10s} {'adj. cosine':>12s}")
    for r in summary:
        print(f"{r['group']:32s} {r['params']:>10d} {r['mean_adjacent_cosine']:>12.4f}")

    big = [r for r in summary[: max(1, len(summary) // 3)]]
    small = [r for r in summary[-max(1, len(summary) // 3):]]
    avg = lambda rs: sum(r["mean_adjacent_cosine"] for r in rs) / len(rs)
    print(f"\nparameter-dominant groups mean cosine: {avg(big):.4f}")
    print(f"smallest groups mean cosine          : {avg(small):.4f}")


if __name__ == "__main__":
    main()
