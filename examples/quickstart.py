"""Quickstart: compress one gradient tensor with GradESTC.

Shows the raw codec API on a single reshaped gradient matrix: init round,
three update rounds against temporally-correlated gradients, bytes on the
wire vs raw, and the reconstruction error.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gradestc as ge
from repro.core.metrics import bytes_h
from repro.core.reshaping import matrix_to_tensor, reshape_to_matrix


def main():
    rng = np.random.default_rng(0)
    # a fake (d_in=512, d_out=384) weight-gradient evolving slowly over rounds
    U = np.linalg.qr(rng.normal(size=(512, 12)))[0]

    def next_grad():
        nonlocal U
        U = np.linalg.qr(U + 0.01 * rng.normal(size=U.shape))[0]
        W = U @ rng.normal(size=(12, 384))
        return jnp.asarray(W + 0.02 * rng.normal(size=W.shape), jnp.float32)

    grad = next_grad()
    # Orientation matters (paper Sec. III-A: "align l with natural structural
    # boundaries"): the persistent factor U lives in the 512-dim column
    # space of W, so the codec basis must span columns of W -- i.e. the
    # length-l segments must walk down columns.  Row-major flattening makes
    # segments out of *rows*, so we transpose first (the production path,
    # repro.launch.steps._delta_to_G, picks this orientation automatically;
    # it also aligns l with the tensor-parallel shard axis -- DESIGN.md S5).
    orig_shape = grad.shape
    G, _, l = reshape_to_matrix(grad.T, l=512)
    m = G.shape[1]
    k, d = 16, 8
    print(f"gradient {orig_shape} -> G ({l} x {m}), k={k}, d={d}")
    print(f"raw uplink per round: {bytes_h(G.size * 4)}")

    state = ge.init_compressor(l, k, jax.random.PRNGKey(0))
    server = ge.DecompressorState(M=jnp.zeros((l, k)))

    for rnd in range(4):
        G, _, _ = reshape_to_matrix(next_grad().T, l)
        if rnd == 0:
            state, payload, stats = ge.compress_init(state, G, k=k)
            server, Ghat = ge.decompress(server, payload, init_basis=state.M)
        else:
            state, payload, stats = ge.compress_update(state, G, k=k, d=d)
            server, Ghat = ge.decompress(server, payload)
        wire = int(ge.payload_scalars(payload, l=l, m=m, k=k))
        recon = matrix_to_tensor(Ghat, orig_shape[::-1]).T
        print(f"round {rnd}: wire={bytes_h(wire):>12s}  "
              f"replaced={int(stats.d_r):2d}/{k} basis vectors  "
              f"rel_err={float(stats.recon_err):.4f}  "
              f"ratio={wire / (G.size * 4):.4f}")
        assert recon.shape == orig_shape

    print("\nServer basis synchronized:",
          bool(jnp.allclose(server.M, state.M, atol=1e-6)))


if __name__ == "__main__":
    main()
