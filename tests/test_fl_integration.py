"""End-to-end FL integration: learning + exact communication accounting."""

import numpy as np
import pytest

from repro.fl import FLConfig, run_fl
from repro.fl.compression import make_method
from repro.core.policy import make_policy


def _cfg(method, rounds=8, **kw):
    return FLConfig(
        method=method, rounds=rounds, n_clients=4, local_steps=2,
        batch=8, seq=32, eval_every=rounds - 1, seed=1, **kw
    )


class TestLearning:
    def test_fedavg_learns(self):
        res = run_fl(_cfg("fedavg", rounds=10))
        assert res.eval_loss[-1] < res.eval_loss[0] - 0.05

    def test_gradestc_learns_and_saves_uplink(self):
        base = run_fl(_cfg("fedavg", rounds=10))
        res = run_fl(_cfg("gradestc", rounds=10))
        # learning comparable to FedAvg
        assert res.eval_loss[-1] < res.eval_loss[0] - 0.05
        assert res.eval_loss[-1] < base.eval_loss[-1] + 0.15
        # uplink strictly smaller (paper's headline claim)
        assert res.ledger.uplink_total < 0.6 * base.ledger.uplink_total

    @pytest.mark.parametrize("method", ["topk", "fedpaq", "signsgd", "fedqclip"])
    def test_baselines_run_and_save(self, method):
        base_total = run_fl(_cfg("fedavg", rounds=4)).ledger.uplink_total
        res = run_fl(_cfg(method, rounds=4))
        assert np.isfinite(res.eval_loss[-1])
        assert res.ledger.uplink_total < base_total

    def test_svdfed_runs(self):
        res = run_fl(_cfg("svdfed", rounds=6))
        assert np.isfinite(res.eval_loss[-1])

    def test_non_iid_runs(self):
        res = run_fl(_cfg("gradestc", rounds=6, alpha=0.1))
        assert np.isfinite(res.eval_loss[-1])

    def test_partial_participation(self):
        res = run_fl(_cfg("gradestc", rounds=6, participation=0.5))
        assert np.isfinite(res.eval_loss[-1])


class TestAblations:
    """Paper Table IV: GradESTC-first / -all / -k vs full."""

    def test_variants_run_with_expected_cost_ordering(self):
        totals = {}
        sum_d = {}
        for variant in ("gradestc", "gradestc-all", "gradestc-k", "gradestc-first"):
            res = run_fl(_cfg(variant, rounds=8))
            totals[variant] = res.ledger.uplink_total
            sum_d[variant] = res.extra.get("sum_d", 0)
            assert np.isfinite(res.eval_loss[-1])
        # -all re-initializes every round -> most uplink
        assert totals["gradestc-all"] >= totals["gradestc"]
        # -first sends only coefficients -> least uplink
        assert totals["gradestc-first"] <= totals["gradestc"]
        # dynamic d does less SVD work than fixed d = k
        assert sum_d["gradestc"] <= sum_d["gradestc-k"]

    def test_error_feedback_variant(self):
        res = run_fl(_cfg("gradestc-ef", rounds=8))
        assert np.isfinite(res.eval_loss[-1])


class TestAccounting:
    def test_fedavg_charges_exact_model_size(self):
        from repro.fl.simulation import default_tiny_arch
        from repro.models import param_group_shapes
        arch = default_tiny_arch()
        n_params = sum(
            int(np.prod(s)) * st for s, st in param_group_shapes(arch).values()
        )
        cfg = _cfg("fedavg", rounds=2)
        res = run_fl(cfg)
        expected = n_params * 4 * cfg.n_clients * 2   # f32, all clients, 2 rounds
        np.testing.assert_allclose(res.ledger.uplink_total, expected, rtol=1e-6)

    def test_gradestc_round0_charges_init_cost(self):
        """Round 0 ships the full basis (k*l extra per group); once the
        basis adapts, steady-state rounds must be cheaper."""
        res = run_fl(_cfg("gradestc", rounds=8))
        per_round = res.ledger.per_round_uplink
        assert len(per_round) == 8
        # round 0 includes init basis; late rounds ship d_r < k vectors
        assert min(per_round[4:]) < per_round[0]
        # every round charges at least the coefficients + raw groups
        assert min(per_round) > 0


class TestDownlinkCompression:
    """Paper Sec. VI future work: compress the server broadcast too."""

    def test_downlink_saves_and_still_learns(self):
        base = run_fl(_cfg("gradestc", rounds=8))
        cfg = _cfg("gradestc", rounds=8)
        cfg.downlink_compress = True
        res = run_fl(cfg)
        assert res.ledger.downlink_total < 0.6 * base.ledger.downlink_total
        assert res.eval_loss[-1] < res.eval_loss[0] - 0.03
