"""Fused round engine vs the per-client reference loop (DESIGN.md Sec. 8-9).

The loop path is the parity oracle: same seeds, same data draws, same
fold_in key chains, and -- since both engines share the codec protocol and
``RoundAccountant`` -- the same exact-integer byte accounting.  The fused
engine must reproduce the loop's eval-loss trajectory to float tolerance
and its uplink/downlink byte accounting *exactly*, for every method.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics
from repro.core.codecs import (
    FedPAQCodec, FedQClipCodec, GradESTCCodec, SignSGDCodec, SVDFedCodec,
    TopKCodec, round_base_key,
)
from repro.core.policy import LayerPlan
from repro.core.reshaping import pad_to_block
from repro.fl import FLConfig, run_fl

#: All seven uplink methods of the paper's Table III comparison.  Codecs
#: whose output is a *discrete* function of the input get a looser loss
#: tolerance: batching local training over clients (vmap) schedules the
#: matmul reductions differently than per-client dispatch, so deltas drift
#: by ~1e-7 -- enough to flip a near-tied top-k index or a stochastic-
#: rounding draw, which moves one weight by a whole entry / quantization
#: step.  Byte accounting stays exactly equal in all cases.
METHODS = [
    ("fedavg", 1e-5),
    ("topk", 5e-4),
    ("fedpaq", 5e-4),
    ("signsgd", 1e-5),
    ("fedqclip", 5e-4),
    ("svdfed", 1e-5),
    ("gradestc", 1e-5),
]


def _cfg(**kw):
    base = dict(method="gradestc", rounds=6, n_clients=4, local_steps=1,
                batch=4, seq=16, eval_every=2, seed=1)
    base.update(kw)
    return FLConfig(**base)


def _assert_parity(loop, fused, atol=1e-5):
    assert loop.extra["engine"] == "loop"
    assert fused.extra["engine"] == "fused"
    np.testing.assert_allclose(fused.eval_loss, loop.eval_loss, rtol=0, atol=atol)
    # byte accounting is exact, not approximate
    assert fused.ledger.per_round_uplink == loop.ledger.per_round_uplink
    assert fused.ledger.uplink_total == loop.ledger.uplink_total
    assert fused.ledger.downlink_total == loop.ledger.downlink_total
    assert fused.uplink_bytes == loop.uplink_bytes
    assert fused.extra.get("sum_d") == loop.extra.get("sum_d")


class TestFusedLoopParity:
    @pytest.mark.parametrize("method,atol", METHODS)
    def test_all_methods_trajectory_and_accounting(self, method, atol):
        """Every Table III method runs fused -- no loop fall-back -- and
        matches the reference loop in loss and exact bytes."""
        kw = dict(method=method, rounds=5)
        loop = run_fl(_cfg(engine="loop", **kw))
        fused = run_fl(_cfg(engine="fused", **kw))
        _assert_parity(loop, fused, atol=atol)

    def test_partial_participation_parity(self):
        """Mixed init/update rounds (stragglers initializing late)."""
        kw = dict(participation=0.5, n_clients=6, rounds=5)
        loop = run_fl(_cfg(engine="loop", **kw))
        fused = run_fl(_cfg(engine="fused", **kw))
        _assert_parity(loop, fused)

    def test_partial_participation_stateful_baseline(self):
        kw = dict(method="topk", participation=0.5, n_clients=6, rounds=4)
        loop = run_fl(_cfg(engine="loop", **kw))
        fused = run_fl(_cfg(engine="fused", **kw))
        _assert_parity(loop, fused, atol=5e-4)

    @pytest.mark.parametrize("method", ["gradestc-first", "gradestc-ef",
                                        "gradestc-all", "gradestc-k"])
    def test_variant_parity(self, method):
        kw = dict(method=method, rounds=4, eval_every=3)
        loop = run_fl(_cfg(engine="loop", **kw))
        fused = run_fl(_cfg(engine="fused", **kw))
        _assert_parity(loop, fused)

    @pytest.mark.parametrize("method", ["gradestc", "topk"])
    def test_downlink_codec_parity(self, method):
        """The downlink codec runs in-jit in the fused engine (no loop
        fall-back) and charges exactly what it ships, on both engines."""
        kw = dict(method=method, rounds=4, downlink_compress=True)
        loop = run_fl(_cfg(engine="loop", **kw))
        fused = run_fl(_cfg(engine="fused", **kw))
        _assert_parity(loop, fused, atol=1e-5 if method == "gradestc" else 5e-4)
        raw = run_fl(_cfg(engine="fused", method=method, rounds=4))
        assert fused.ledger.downlink_total < raw.ledger.downlink_total

    @pytest.mark.parametrize("method", ["gradestc", "fedpaq", "topk", "svdfed"])
    def test_single_host_sync_per_chunk(self, method):
        """The scan engine's contract: one device->host fetch per K-round
        chunk, for every method (any codec that silently fell back to
        per-value fetches would fail this).  Eval rounds add exactly one
        measured fetch each -- the stacked-batch eval, not one float() per
        batch.  With K=1 this degrades to exactly one fetch per round."""
        rounds = 6
        metrics.reset_host_sync_count()
        res = run_fl(_cfg(method=method, engine="fused", rounds=rounds,
                          eval_every=100, scan_rounds=4))
        assert res.extra["engine"] == "fused"
        # chunks: (0,1) [round-0 eval], (1,5), (5,6) [final eval]
        assert res.extra["chunks"] == 3
        assert metrics.host_sync_count() == (res.extra["chunks"]
                                             + len(res.eval_rounds))

        metrics.reset_host_sync_count()
        res1 = run_fl(_cfg(method=method, engine="fused", rounds=rounds,
                           eval_every=100, scan_rounds=1))
        assert res1.extra["chunks"] == rounds
        assert metrics.host_sync_count() == rounds + len(res1.eval_rounds)

    def test_loop_obeys_same_sync_budget(self):
        """The reference loop routes byte accounting through the same
        packed-stats path: one measured fetch per round (it used to pay one
        blocking ``float(sc)`` per (client, tensor)), plus one per eval."""
        rounds = 3
        for method in ("gradestc", "topk"):
            metrics.reset_host_sync_count()
            res = run_fl(_cfg(method=method, engine="loop", rounds=rounds,
                              eval_every=100))
            assert res.extra["engine"] == "loop"
            assert metrics.host_sync_count() == rounds + len(res.eval_rounds)

    def test_scan_chunking_invariance(self):
        """The chunk length K is pure dispatch amortization: every K must
        produce the identical trajectory and the identical ledger, byte for
        byte (chunks never span an eval round, so the eval cadence is also
        invariant)."""
        runs = {k: run_fl(_cfg(engine="fused", rounds=7, scan_rounds=k))
                for k in (1, 3, 8)}
        ref = runs[1]
        for k in (3, 8):
            np.testing.assert_allclose(runs[k].eval_loss, ref.eval_loss,
                                       rtol=0, atol=1e-7)
            assert runs[k].eval_rounds == ref.eval_rounds
            assert (runs[k].ledger.per_round_uplink
                    == ref.ledger.per_round_uplink)
            assert runs[k].ledger.uplink_total == ref.ledger.uplink_total
            assert runs[k].extra["chunks"] < ref.extra["chunks"]

    def test_no_mid_run_recompiles(self):
        """The rank-padded traced-d contract, measured two ways: the chunk
        program compiles exactly once per distinct chunk length, and the
        jax.monitoring compile-event stream goes silent once every shape
        has been seen -- Formula 13 moving d between rounds (which used to
        re-bucket a jit-static arg and redispatch) must not trigger a
        single extra XLA compile."""
        from repro.launch.compile_cache import CompileWatcher

        watcher = CompileWatcher.install()
        mark = watcher.snapshot()
        # chunks: (0,1), (1,5), (5,9) -- the last repeats shape 4, so by
        # its dispatch every shape (and the eval program) is compiled.
        res = run_fl(_cfg(engine="fused", rounds=9, scan_rounds=4,
                          eval_every=100))
        assert res.extra["chunk_shapes"] == 2      # {1, 4}
        if res.extra["chunk_compiles"] >= 0:       # -1 = counter unavailable
            assert res.extra["chunk_compiles"] == res.extra["chunk_shapes"]
        spans = res.extra["chunk_spans"]
        assert len(spans) == 3
        n_after, _ = watcher.since(mark, t_start=spans[-1][0])
        assert n_after == 0, "steady-state chunk triggered an XLA compile"

    def test_pallas_encode_inside_engine_matches(self):
        """use_pallas routes A/E through the kernel (interpret on CPU) and
        must not change the trajectory or the accounting."""
        ref = run_fl(_cfg(engine="fused", rounds=4, use_pallas=False))
        pal = run_fl(_cfg(engine="fused", rounds=4, use_pallas=True))
        assert pal.extra["use_pallas"] is True
        np.testing.assert_allclose(pal.eval_loss, ref.eval_loss, rtol=0, atol=1e-6)
        assert pal.ledger.per_round_uplink == ref.ledger.per_round_uplink

    @pytest.mark.parametrize("method", ["fedpaq", "fedqclip"])
    def test_pallas_block_quantizer_parity(self, method):
        """The quantization codecs take the Pallas block quantizer under the
        same use_pallas flag; engines still agree exactly on bytes (the
        block-local wire format charges one scale per block)."""
        kw = dict(method=method, rounds=3, use_pallas=True)
        loop = run_fl(_cfg(engine="loop", **kw))
        fused = run_fl(_cfg(engine="fused", **kw))
        _assert_parity(loop, fused, atol=5e-4)
        glob = run_fl(_cfg(engine="fused", method=method, rounds=3,
                           use_pallas=False))
        # block-local scales cost more wire than one global scale
        assert fused.ledger.uplink_total > glob.ledger.uplink_total


# ---------------------------------------------------------------------------
# codec protocol properties: shape polymorphism under vmap
# ---------------------------------------------------------------------------

def _codecs_under_test():
    plan = LayerPlan(name="g", shape=(24, 16), stack=2, l=24, m=16, k=4,
                     compress=True)
    n = plan.raw_scalars
    return plan, [
        TopKCodec(n, frac=0.1),
        FedPAQCodec(n, bits=8),
        FedPAQCodec(n, bits=8, use_pallas=True, pallas_interpret=True),
        SignSGDCodec(n),
        FedQClipCodec(n, clip=10.0),
        SVDFedCodec(plan, gamma=8.0, seed=0),
        GradESTCCodec(plan, seed=0, variant="full"),
    ]


class TestCodecProtocol:
    """Every codec's encode must be shape-polymorphic under vmap over the
    client axis -- traced state only, no Python-int leakage."""

    @pytest.mark.parametrize("n_clients", [1, 3, 5])
    def test_encode_vmaps_over_any_client_count(self, n_clients):
        plan, codecs = _codecs_under_test()
        for codec in codecs:
            cstate = codec.init_client_state(n_clients)
            shared = codec.init_shared_state()
            base = round_base_key(0, 0)
            keys = jax.vmap(
                lambda c, _co=codec: _co.per_client_key(base, c)
            )(jnp.arange(n_clients))
            delta = jax.random.normal(
                jax.random.PRNGKey(3),
                (n_clients, plan.stack) + plan.shape, jnp.float32)
            wire = jax.vmap(codec.to_wire)(delta)

            def enc(cs, k, w, _co=codec, _sh=shared):
                return _co.encode(cs, _sh, k, w)

            cst2, recon, stats = jax.vmap(enc)(cstate, keys, wire)
            assert recon.shape == wire.shape, codec
            assert stats.shape == (n_clients, codec.client_stats_len), codec
            assert stats.dtype == jnp.int32
            red = codec.reduce_stats(stats)
            assert red.shape == (codec.stats_len,), codec
            # state shapes are preserved (so the engine can scatter back)
            for a, b in zip(jax.tree.leaves(cst2), jax.tree.leaves(cstate)):
                assert a.shape == b.shape, codec

    def test_encode_traces_abstractly(self):
        """eval_shape succeeds: no concrete-value dependence inside encode
        (a Python int leaking from traced state would raise here)."""
        plan, codecs = _codecs_under_test()
        for codec in codecs:
            cstate = codec.init_client_state(2)
            shared = codec.init_shared_state()
            wire = jnp.zeros((2, plan.stack, plan.l, plan.m), jnp.float32)
            flat = jnp.zeros((2, plan.raw_scalars), jnp.float32)
            w = wire if isinstance(codec, (SVDFedCodec, GradESTCCodec)) else flat
            key = jax.random.PRNGKey(0)

            def enc(cs, w_, _co=codec, _sh=shared, _k=key):
                return _co.encode(cs, _sh, _k, w_)

            jax.eval_shape(jax.vmap(enc, in_axes=(0, 0)), cstate, w)

    def test_round_trip_reconstruction_shapes(self):
        plan, codecs = _codecs_under_test()
        delta = jax.random.normal(jax.random.PRNGKey(5),
                                  (plan.stack,) + plan.shape, jnp.float32)
        for codec in codecs:
            wire = codec.to_wire(delta)
            back = codec.from_wire(wire, delta.shape)
            assert back.shape == delta.shape
            # to/from wire is an exact (reshape-only) round trip
            np.testing.assert_array_equal(np.asarray(back), np.asarray(delta))


class TestPaddedEncodeKernel:
    """encode_pallas only accepts m % block_m == 0; the ops.encode wrapper
    (and the engine through it) pads via core/reshaping.pad_to_block."""

    @pytest.mark.parametrize("l,k,m", [(96, 8, 100), (64, 4, 37), (256, 16, 200)])
    def test_non_128_multiple_m_matches_einsum(self, l, k, m, key):
        from repro.kernels.ops import encode

        Mq, _ = jnp.linalg.qr(jax.random.normal(key, (l, k), jnp.float32))
        G = jax.random.normal(jax.random.PRNGKey(7), (l, m), jnp.float32)
        A1, E1 = encode(Mq, G, interpret=True)
        A0 = jnp.einsum("lk,lm->km", Mq, G)
        E0 = G - jnp.einsum("lk,km->lm", Mq, A0)
        assert A1.shape == (k, m) and E1.shape == (l, m)
        np.testing.assert_allclose(np.asarray(A1), np.asarray(A0), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(E1), np.asarray(E0), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("l,k,m", [(96, 8, 100), (64, 4, 37)])
    def test_direct_pallas_call_on_padded_input(self, l, k, m, key):
        from repro.kernels.gradestc_encode import encode_pallas

        Mq, _ = jnp.linalg.qr(jax.random.normal(key, (l, k), jnp.float32))
        G = jax.random.normal(jax.random.PRNGKey(8), (l, m), jnp.float32)
        Gp, m0 = pad_to_block(G, 128, axis=-1)
        assert m0 == m and Gp.shape[-1] % 128 == 0
        A, E = encode_pallas(Mq, Gp, block_m=128, interpret=True)
        A, E = A[:, :m], E[:, :m]
        A0 = jnp.einsum("lk,lm->km", Mq, G)
        np.testing.assert_allclose(np.asarray(A), np.asarray(A0), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(E), np.asarray(G - Mq @ A0),
                                   rtol=1e-4, atol=1e-4)

    def test_pad_to_block_noop_and_zero_fill(self):
        x = jnp.ones((3, 128))
        same, m0 = pad_to_block(x, 128, axis=-1)
        assert same is x and m0 == 128
        padded, m0 = pad_to_block(jnp.ones((3, 100)), 128, axis=-1)
        assert padded.shape == (3, 128) and m0 == 100
        assert float(jnp.abs(padded[:, 100:]).max()) == 0.0
