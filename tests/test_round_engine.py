"""Fused round engine vs the per-client reference loop (DESIGN.md Sec. 8).

The loop path is the parity oracle: same seeds, same data draws, same
fold_in key chains -- the fused engine must reproduce its eval-loss
trajectory to float tolerance and its uplink byte accounting *exactly*.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics
from repro.core.reshaping import pad_to_block
from repro.fl import FLConfig, run_fl


def _cfg(**kw):
    base = dict(method="gradestc", rounds=6, n_clients=4, local_steps=1,
                batch=4, seq=16, eval_every=2, seed=1)
    base.update(kw)
    return FLConfig(**base)


def _assert_parity(loop, fused, atol=1e-5):
    assert loop.extra["engine"] == "loop"
    assert fused.extra["engine"] == "fused"
    np.testing.assert_allclose(fused.eval_loss, loop.eval_loss, rtol=0, atol=atol)
    # byte accounting is exact, not approximate
    assert fused.ledger.per_round_uplink == loop.ledger.per_round_uplink
    assert fused.ledger.uplink_total == loop.ledger.uplink_total
    assert fused.uplink_bytes == loop.uplink_bytes
    assert fused.extra.get("sum_d") == loop.extra.get("sum_d")


class TestFusedLoopParity:
    def test_trajectory_and_accounting_match(self):
        loop = run_fl(_cfg(engine="loop"))
        fused = run_fl(_cfg(engine="fused"))
        _assert_parity(loop, fused)

    def test_partial_participation_parity(self):
        """Mixed init/update rounds (stragglers initializing late)."""
        kw = dict(participation=0.5, n_clients=6, rounds=5)
        loop = run_fl(_cfg(engine="loop", **kw))
        fused = run_fl(_cfg(engine="fused", **kw))
        _assert_parity(loop, fused)

    @pytest.mark.parametrize("method", ["gradestc-first", "gradestc-ef", "fedavg"])
    def test_variant_parity(self, method):
        kw = dict(method=method, rounds=4, eval_every=3)
        loop = run_fl(_cfg(engine="loop", **kw))
        fused = run_fl(_cfg(engine="fused", **kw))
        _assert_parity(loop, fused)

    def test_single_host_sync_per_round(self):
        """The fused engine's contract: one device->host fetch per round."""
        rounds = 5
        metrics.reset_host_sync_count()
        run_fl(_cfg(engine="fused", rounds=rounds, eval_every=100))
        assert metrics.host_sync_count() == rounds

    def test_loop_syncs_scale_with_clients(self):
        """Sanity on the counter itself: the reference loop syncs at least
        once per (client, compressed group) per steady round."""
        metrics.reset_host_sync_count()
        res = run_fl(_cfg(engine="loop", rounds=3, eval_every=100))
        assert res.extra["engine"] == "loop"
        assert metrics.host_sync_count() > 3 * 4    # rounds * clients

    def test_unsupported_method_falls_back_to_loop(self):
        res = run_fl(_cfg(method="topk", engine="fused", rounds=2, eval_every=1))
        assert res.extra["engine"] == "loop"

    def test_pallas_encode_inside_engine_matches(self):
        """use_pallas routes A/E through the kernel (interpret on CPU) and
        must not change the trajectory or the accounting."""
        ref = run_fl(_cfg(engine="fused", rounds=4, use_pallas=False))
        pal = run_fl(_cfg(engine="fused", rounds=4, use_pallas=True))
        assert pal.extra["use_pallas"] is True
        np.testing.assert_allclose(pal.eval_loss, ref.eval_loss, rtol=0, atol=1e-6)
        assert pal.ledger.per_round_uplink == ref.ledger.per_round_uplink


class TestPaddedEncodeKernel:
    """encode_pallas only accepts m % block_m == 0; the ops.encode wrapper
    (and the engine through it) pads via core/reshaping.pad_to_block."""

    @pytest.mark.parametrize("l,k,m", [(96, 8, 100), (64, 4, 37), (256, 16, 200)])
    def test_non_128_multiple_m_matches_einsum(self, l, k, m, key):
        from repro.kernels.ops import encode

        Mq, _ = jnp.linalg.qr(jax.random.normal(key, (l, k), jnp.float32))
        G = jax.random.normal(jax.random.PRNGKey(7), (l, m), jnp.float32)
        A1, E1 = encode(Mq, G, interpret=True)
        A0 = jnp.einsum("lk,lm->km", Mq, G)
        E0 = G - jnp.einsum("lk,km->lm", Mq, A0)
        assert A1.shape == (k, m) and E1.shape == (l, m)
        np.testing.assert_allclose(np.asarray(A1), np.asarray(A0), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(E1), np.asarray(E0), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("l,k,m", [(96, 8, 100), (64, 4, 37)])
    def test_direct_pallas_call_on_padded_input(self, l, k, m, key):
        from repro.kernels.gradestc_encode import encode_pallas

        Mq, _ = jnp.linalg.qr(jax.random.normal(key, (l, k), jnp.float32))
        G = jax.random.normal(jax.random.PRNGKey(8), (l, m), jnp.float32)
        Gp, m0 = pad_to_block(G, 128, axis=-1)
        assert m0 == m and Gp.shape[-1] % 128 == 0
        A, E = encode_pallas(Mq, Gp, block_m=128, interpret=True)
        A, E = A[:, :m], E[:, :m]
        A0 = jnp.einsum("lk,lm->km", Mq, G)
        np.testing.assert_allclose(np.asarray(A), np.asarray(A0), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(E), np.asarray(G - Mq @ A0),
                                   rtol=1e-4, atol=1e-4)

    def test_pad_to_block_noop_and_zero_fill(self):
        x = jnp.ones((3, 128))
        same, m0 = pad_to_block(x, 128, axis=-1)
        assert same is x and m0 == 128
        padded, m0 = pad_to_block(jnp.ones((3, 100)), 128, axis=-1)
        assert padded.shape == (3, 128) and m0 == 100
        assert float(jnp.abs(padded[:, 100:]).max()) == 0.0
