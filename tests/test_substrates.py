"""Substrate tests: optimizers, schedules, data pipeline, checkpointing,
compression policy, and the launch-layer delta<->matrix plumbing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core.policy import LayerPlan, make_policy, coverage
from repro.data import client_batch_stream, make_task
from repro.data.partition import dirichlet_client_priors, iid_client_priors
from repro.optim import adam, cosine_decay, constant, linear_warmup, sgd


class TestOptim:
    def _quad(self, opt_init, opt_update, steps=200):
        params = {"x": jnp.asarray([3.0, -2.0])}
        st = opt_init(params)
        for _ in range(steps):
            g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
            params, st = opt_update(g, st, params)
        return float(jnp.abs(params["x"]).max())

    def test_sgd_converges(self):
        assert self._quad(*sgd(0.1)) < 1e-3

    def test_sgd_momentum_converges(self):
        assert self._quad(*sgd(0.05, momentum=0.9)) < 1e-3

    def test_adam_converges(self):
        assert self._quad(*adam(0.1)) < 1e-2

    def test_schedules(self):
        s = cosine_decay(1.0, 100, warmup_steps=10)
        assert float(s(jnp.asarray(0))) == 0.0
        assert float(s(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
        assert float(s(jnp.asarray(100))) < 0.01
        w = linear_warmup(2.0, 4)
        assert float(w(jnp.asarray(2))) == pytest.approx(1.0)
        assert float(constant(0.3)(jnp.asarray(7))) == pytest.approx(0.3)


class TestData:
    def test_priors(self):
        p = iid_client_priors(5, 8)
        np.testing.assert_allclose(p.sum(1), 1.0)
        d = dirichlet_client_priors(5, 8, 0.1)
        np.testing.assert_allclose(d.sum(1), 1.0, rtol=1e-5)
        # low alpha -> skewed
        assert d.max() > 0.5

    def test_stream_shapes_and_determinism(self):
        task = make_task(vocab=64, n_clients=3, alpha=0.5, seed=3)
        s1 = client_batch_stream(task, 0, 4, 16, seed=9)
        s2 = client_batch_stream(task, 0, 4, 16, seed=9)
        b1, b2 = next(s1), next(s2)
        assert b1["tokens"].shape == (4, 16)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
        # labels are next tokens
        x1 = next(s1)
        assert x1["tokens"].shape == x1["labels"].shape

    def test_clients_differ_under_noniid(self):
        task = make_task(vocab=64, n_clients=3, alpha=0.1, seed=3)
        b0 = next(client_batch_stream(task, 0, 8, 64, seed=1))
        b1 = next(client_batch_stream(task, 1, 8, 64, seed=1))
        h0 = np.bincount(np.asarray(b0["tokens"]).ravel(), minlength=64)
        h1 = np.bincount(np.asarray(b1["tokens"]).ravel(), minlength=64)
        # token histograms materially different
        assert np.abs(h0 - h1).sum() > 0.2 * h0.sum()

    def test_chain_is_learnable(self):
        """The transition structure must be sharp enough to learn."""
        task = make_task(vocab=64, n_clients=2, seed=0)
        ent = -np.sum(task.trans * np.log(task.trans + 1e-12), axis=1).mean()
        assert ent < 0.7 * np.log(64)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "layers": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
            "opt": (jnp.zeros(3, jnp.bfloat16), jnp.asarray(7)),
        }
        path = str(tmp_path / "ck")
        ckpt.save(path, 42, tree)
        assert ckpt.latest_step(path) == 42
        out = ckpt.restore(path, 42, tree)
        np.testing.assert_array_equal(
            np.asarray(out["layers"]["w"]), np.asarray(tree["layers"]["w"]))
        assert out["opt"][0].dtype == jnp.bfloat16
        assert int(out["opt"][1]) == 7

    def test_atomic_overwrite(self, tmp_path):
        path = str(tmp_path / "ck")
        ckpt.save(path, 1, {"a": jnp.ones(4)})
        ckpt.save(path, 2, {"a": jnp.ones(4) * 2})
        assert ckpt.latest_step(path) == 2
        out = ckpt.restore(path, 2, {"a": jnp.zeros(4)})
        np.testing.assert_array_equal(np.asarray(out["a"]), 2 * np.ones(4))


class TestPolicy:
    def test_parameter_dominant_selection(self):
        shapes = {
            "big": ((1024, 1024), 8),
            "small": ((64, 64), 8),
            "embed": ((5000, 64), 1),
            "norm": ((64,), 9),
        }
        p = make_policy(shapes, min_params=1000)
        assert p.plans["big"].compress
        assert not p.plans["embed"].compress      # excluded by name
        assert not p.plans["norm"].compress
        assert coverage(p) > 0.5

    def test_formula14_scalars(self):
        lp = LayerPlan(name="g", shape=(256, 512), stack=4, l=512, m=256,
                       k=16, compress=True)
        assert lp.update_scalars(d_r=3) == (16 * 256 + 3 * 512 + 3) * 4
        assert lp.init_scalars == (16 * 512 + 16 * 256) * 4
        assert lp.raw_scalars == 256 * 512 * 4


class TestLaunchPlumbing:
    """_delta_to_G / _G_to_delta must be exact inverses for every plan."""

    @pytest.mark.parametrize("shape,l", [
        ((64, 48), 48), ((64, 48), 64), ((8, 32, 16), 32), ((8, 32, 16), 16),
        ((128, 96), 32),   # l not a tensor dim -> generic segment path
    ])
    def test_roundtrip(self, shape, l):
        from repro.launch.steps import _delta_to_G, _G_to_delta
        n = int(np.prod(shape))
        lp = LayerPlan(name="t", shape=shape, stack=3, l=l, m=n // l,
                       k=4, compress=True)
        rng = np.random.default_rng(0)
        delta = jnp.asarray(rng.normal(size=(2, 3) + shape), jnp.float32)
        G = _delta_to_G(delta, lp)
        assert G.shape == (2, 3, l, n // l)
        back = _G_to_delta(G, lp, delta.shape)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(delta))
