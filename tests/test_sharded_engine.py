"""Sharded fused round engine vs single-device fused vs loop (DESIGN.md
Sec. 10).

The sharded engine must be **ledger-exact** against the single-device fused
program (and, transitively, the reference loop): identical uplink/downlink
byte counts for every method, eval-loss trajectories to float tolerance.
The full matrix runs in the CI multi-device job
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``); on a plain
single-device run, a subprocess smoke test keeps the sharded path covered.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import metrics
from repro.fl import FLConfig, run_fl

# all seven Table III methods with their per-method loss tolerances --
# shared with the fused-vs-loop parity matrix so the two suites cannot
# silently enforce different bars (byte accounting is exactly equal in
# every case regardless).
from test_round_engine import METHODS

NDEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    NDEV < 8,
    reason="needs 8 host-platform devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _cfg(**kw):
    base = dict(method="gradestc", rounds=4, n_clients=8, local_steps=1,
                batch=4, seq=16, eval_every=2, seed=1)
    base.update(kw)
    return FLConfig(**base)


def _assert_parity(shard, ref, atol=1e-5):
    np.testing.assert_allclose(shard.eval_loss, ref.eval_loss, rtol=0,
                               atol=atol)
    # the acceptance bar: sharding must not move a single ledger byte
    assert shard.ledger.per_round_uplink == ref.ledger.per_round_uplink
    assert shard.ledger.uplink_total == ref.ledger.uplink_total
    assert shard.ledger.downlink_total == ref.ledger.downlink_total
    assert shard.uplink_bytes == ref.uplink_bytes
    assert shard.extra.get("sum_d") == ref.extra.get("sum_d")


@needs8
class TestShardedParity:
    @pytest.mark.parametrize("method,atol", METHODS)
    def test_all_methods_ledger_exact(self, method, atol):
        single = run_fl(_cfg(method=method))
        shard = run_fl(_cfg(method=method, devices=8))
        assert shard.extra["devices"] == 8
        _assert_parity(shard, single, atol)

    def test_sharded_vs_loop(self):
        """Transitivity guard: the sharded engine pins directly to the
        reference loop, not only to the single-device fused program."""
        loop = run_fl(_cfg(engine="loop"))
        shard = run_fl(_cfg(devices=8))
        _assert_parity(shard, loop)

    def test_nondivisible_client_count_padding(self):
        """n_sel=6 on an 8-way mesh: two padding lanes mirror client sel[0]
        and are masked out of the mean/stats; bytes stay exact."""
        kw = dict(n_clients=10, participation=0.6)
        single = run_fl(_cfg(**kw))
        shard = run_fl(_cfg(devices=8, **kw))
        assert single.extra["devices"] == 1
        _assert_parity(shard, single)

    def test_partial_participation_mixed_mode(self):
        """Stragglers initializing late (mixed cond rounds) under sharding."""
        kw = dict(n_clients=12, participation=0.5, rounds=5)
        single = run_fl(_cfg(**kw))
        shard = run_fl(_cfg(devices=8, **kw))
        _assert_parity(shard, single)

    def test_downlink_codec_sharded(self):
        kw = dict(downlink_compress=True)
        single = run_fl(_cfg(**kw))
        shard = run_fl(_cfg(devices=8, **kw))
        _assert_parity(shard, single)

    def test_scan_chunks_sharded_parity(self):
        """The K-round scan chunk under shard_map: same trajectory and
        ledger as K=1 sharded and as the single-device scan -- and zero
        mid-run recompiles (one executable per chunk shape)."""
        single = run_fl(_cfg(rounds=6, scan_rounds=4))
        shard1 = run_fl(_cfg(rounds=6, devices=8, scan_rounds=1))
        shardk = run_fl(_cfg(rounds=6, devices=8, scan_rounds=4))
        # vs single-device: the psum schedules reductions differently ->
        # float-tolerance; vs K=1 sharded: identical program body -> exact-ish
        _assert_parity(shardk, single, atol=1e-5)
        _assert_parity(shardk, shard1, atol=1e-7)
        assert shardk.extra["chunks"] < shard1.extra["chunks"]
        if shardk.extra["chunk_compiles"] >= 0:    # -1 = counter unavailable
            assert (shardk.extra["chunk_compiles"]
                    == shardk.extra["chunk_shapes"])

    def test_single_host_sync_per_chunk_sharded(self):
        """The per-chunk host-sync contract survives shard_map: one packed
        stats fetch per K-round chunk, plus one fetch per eval round."""
        rounds = 6
        metrics.reset_host_sync_count()
        res = run_fl(_cfg(rounds=rounds, devices=8, eval_every=100,
                          scan_rounds=4))
        assert res.extra["chunks"] == 3       # (0,1), (1,5), (5,6)
        assert metrics.host_sync_count() == (res.extra["chunks"]
                                             + len(res.eval_rounds))


class TestShardedSubprocessSmoke:
    """Keeps the sharded path exercised by the plain (single-device) suite:
    a child process forces 4 host devices and asserts fused-sharded vs
    fused-single parity on a tiny model."""

    @pytest.mark.skipif(NDEV >= 8, reason="covered by TestShardedParity")
    def test_sharded_parity_in_subprocess(self):
        child = r"""
import numpy as np
from repro.fl import FLConfig, run_fl
from repro.models.config import ArchConfig
arch = ArchConfig(name="t", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=128, vocab=64,
                  dtype="float32", remat=False, attn_chunk=0)
kw = dict(method="gradestc", rounds=4, n_clients=6, local_steps=1, batch=2,
          seq=16, eval_every=2, seed=1, arch=arch)
a = run_fl(FLConfig(engine="fused", **kw))
b = run_fl(FLConfig(engine="fused", devices=4, scan_rounds=3, **kw))
np.testing.assert_allclose(b.eval_loss, a.eval_loss, rtol=0, atol=1e-5)
assert b.ledger.per_round_uplink == a.ledger.per_round_uplink
assert b.ledger.uplink_total == a.ledger.uplink_total
print("SHARDED-PARITY-OK")
"""
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4"
                            ).strip()
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run([sys.executable, "-c", child], env=env,
                             capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-3000:]
        assert "SHARDED-PARITY-OK" in out.stdout
