"""Pallas kernel validation: shape/dtype sweep vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gradestc_decode import decode_pallas
from repro.kernels.gradestc_encode import encode_pallas
from repro.kernels.quant import block_dequant_pallas, block_quant_pallas

ENCODE_SHAPES = [
    # (l, k, m, block_m)
    (128, 8, 128, 128),
    (256, 16, 384, 128),
    (512, 32, 256, 256),
    (384, 4, 512, 128),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _orthonormal(key, l, k, dt):
    M, _ = jnp.linalg.qr(jax.random.normal(key, (l, k), jnp.float32))
    return M.astype(dt)


@pytest.mark.parametrize("l,k,m,bm", ENCODE_SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
class TestEncodeKernel:
    def test_matches_oracle(self, l, k, m, bm, dt, key):
        M = _orthonormal(key, l, k, dt)
        G = jax.random.normal(jax.random.PRNGKey(1), (l, m), dt)
        A1, E1 = encode_pallas(M, G, block_m=bm, interpret=True)
        A0, E0 = ref.encode_ref(M, G)
        tol = 2e-2 if dt == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(np.asarray(A1, np.float32),
                                   np.asarray(A0, np.float32), rtol=tol, atol=tol)
        np.testing.assert_allclose(np.asarray(E1, np.float32),
                                   np.asarray(E0, np.float32), rtol=tol, atol=tol)

    def test_residual_orthogonal_to_basis(self, l, k, m, bm, dt, key):
        """The kernel must preserve M^T E = 0 (Formula 7)."""
        M = _orthonormal(key, l, k, dt)
        G = jax.random.normal(jax.random.PRNGKey(2), (l, m), dt)
        _, E = encode_pallas(M, G, block_m=bm, interpret=True)
        cross = np.asarray(
            M.astype(jnp.float32).T @ E.astype(jnp.float32)
        )
        scale = float(jnp.abs(G.astype(jnp.float32)).max())
        tol = 5e-2 if dt == jnp.bfloat16 else 1e-3
        assert np.abs(cross).max() < tol * scale * np.sqrt(l)


@pytest.mark.parametrize("l,k,m,bm", ENCODE_SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_decode_kernel(l, k, m, bm, dt, key):
    M = _orthonormal(key, l, k, dt)
    A = jax.random.normal(jax.random.PRNGKey(3), (k, m), dt)
    bl = 128 if l % 128 == 0 else l
    out = decode_pallas(M, A, block_l=bl, block_m=128, interpret=True)
    exp = ref.decode_ref(M, A)
    tol = 2e-2 if dt == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,block,br", [(4096, 512, 4), (2048, 256, 8), (8192, 512, 16)])
@pytest.mark.parametrize("bits", [4, 8])
def test_quant_kernel_bit_exact(n, block, br, bits, key):
    g = jax.random.normal(key, (n,), jnp.float32) * 3.0
    u = jax.random.uniform(jax.random.PRNGKey(5), (n,))
    c1, s1 = block_quant_pallas(g, u, block=block, bits=bits, block_rows=br,
                                interpret=True)
    c0, s0 = ref.block_quant_ref(g, u, block, bits)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c0))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0), rtol=1e-6)
    d1 = block_dequant_pallas(c1, s1, block=block, bits=bits, block_rows=br,
                              interpret=True)
    d0 = ref.block_dequant_ref(c0, s0, block, bits)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d0), rtol=1e-6)


class TestOpsWrappers:
    def test_encode_pads_ragged_m(self, key):
        M = _orthonormal(key, 300, 12, jnp.float32)
        G = jax.random.normal(key, (300, 777))
        A, E = ops.encode(M, G)
        A0, E0 = ref.encode_ref(M, G)
        np.testing.assert_allclose(np.asarray(A), np.asarray(A0), atol=1e-4)
        np.testing.assert_allclose(np.asarray(E), np.asarray(E0), atol=1e-4)

    def test_decode_roundtrip(self, key):
        M = _orthonormal(key, 256, 8, jnp.float32)
        G = jax.random.normal(key, (256, 200))
        A, _ = ops.encode(M, G)
        Ghat = ops.decode(M, A)
        np.testing.assert_allclose(
            np.asarray(Ghat), np.asarray(ref.decode_ref(M, A)), atol=1e-4
        )

    def test_quant_roundtrip_with_padding(self, key):
        g = jax.random.normal(key, (1000,))
        codes, scales, pad = ops.block_quantize(g, key)
        gd = ops.block_dequantize(codes, scales, pad)
        assert gd.shape == g.shape
        step = 2.0 * float(scales.max()) / 127
        assert float(jnp.abs(gd - g).max()) <= step + 1e-5

    def test_choose_block_m_fits_budget(self):
        for l in (512, 4096, 14336, 29568):
            for k in (16, 64, 128):
                for dt in (jnp.float32, jnp.bfloat16):
                    bm = ops.choose_block_m(l, k, dt)
                    s = jnp.dtype(dt).itemsize
                    if bm == 0:
                        # infeasible for single-pass: even bm=128 over budget
                        assert l * k * s + (2 * l + k) * 128 * s > ops.VMEM_BUDGET_BYTES
                    else:
                        assert bm % 128 == 0
                        assert (l * k * s + (2 * l + k) * bm * s
                                <= ops.VMEM_BUDGET_BYTES * 1.25)

    def test_encode_falls_back_for_huge_l(self, key):
        """l too large for VMEM -> XLA path, still correct."""
        M = _orthonormal(key, 29568 // 16, 8, jnp.float32)  # scaled-down check
        assert ops.choose_block_m(29568, 64, jnp.float32) == 0
        G = jax.random.normal(key, (M.shape[0], 64))
        A, E = ops.encode(M, G)
        A0, E0 = ref.encode_ref(M, G)
        np.testing.assert_allclose(np.asarray(A), np.asarray(A0), atol=1e-4)


class TestDecodeWiring:
    """The decode kernel is wired into the GradESTC reconstruction and
    downlink decode paths (``core.gradestc.reconstruct`` / ``decompress``)
    under the same use_pallas flag as encode."""

    def test_reconstruct_routes_through_decode_kernel(self, key):
        from repro.core import gradestc as ge
        M = _orthonormal(key, 96, 8, jnp.float32)
        A = jax.random.normal(jax.random.PRNGKey(7), (8, 100), jnp.float32)
        out = ge.reconstruct(M, A, use_pallas=True, pallas_interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(M @ A),
                                   rtol=1e-4, atol=1e-4)

    def test_decompress_pallas_matches_plain(self, key):
        from repro.core import gradestc as ge
        l, k, d, m = 64, 4, 2, 37
        M = _orthonormal(key, l, k, jnp.float32)
        payload = ge.Payload(
            replaced_mask=jnp.array([True, False, True, False]),
            new_vectors=jax.random.normal(jax.random.PRNGKey(8), (d, l)),
            coeffs=jax.random.normal(jax.random.PRNGKey(9), (k, m)),
            d_r=jnp.asarray(d, jnp.int32),
            init=jnp.zeros((), jnp.bool_),
        )
        st = ge.DecompressorState(M=M)
        st0, g0 = ge.decompress(st, payload)
        st1, g1 = ge.decompress(st, payload, use_pallas=True,
                                pallas_interpret=True)
        np.testing.assert_array_equal(np.asarray(st0.M), np.asarray(st1.M))
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g0),
                                   rtol=1e-4, atol=1e-4)


class TestFlashAttention:
    """Fused flash attention kernel (SPerf, qwen2 prefill) vs the reference
    attention path."""

    @pytest.mark.parametrize("B,Sq,H,KV,hd,causal,window", [
        (2, 128, 4, 2, 32, True, 0),
        (1, 256, 8, 8, 16, True, 64),
        (2, 128, 4, 1, 64, False, 0),
        (1, 192, 6, 3, 32, True, 0),
    ])
    def test_matches_reference(self, B, Sq, H, KV, hd, causal, window, key):
        from repro.kernels.flash_attention import flash_attention_pallas
        from repro.models.layers import attention, repeat_kv
        q = jax.random.normal(key, (B, Sq, H, hd), jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(1), (B, Sq, KV, hd), jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(2), (B, Sq, KV, hd), jnp.float32)
        out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                     block_q=64, block_kv=64, interpret=True)
        exp = attention(q, repeat_kv(k, H // KV), repeat_kv(v, H // KV),
                        causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-4, atol=1e-4)

    def test_bf16(self, key):
        from repro.kernels.flash_attention import flash_attention_pallas
        from repro.models.layers import attention, repeat_kv
        q = jax.random.normal(key, (1, 128, 4, 32), jnp.bfloat16)
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2, 32), jnp.bfloat16)
        v = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 32), jnp.bfloat16)
        out = flash_attention_pallas(q, k, v, block_q=64, block_kv=64,
                                     interpret=True)
        exp = attention(q, repeat_kv(k, 2), repeat_kv(v, 2))
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32),
                                   rtol=5e-2, atol=5e-2)
