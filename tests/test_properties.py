"""Hypothesis property-based tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import gradestc as ge
from repro.core.baselines import (
    dequantize, quantize_stochastic, sign_compress, topk_compress, TopKState,
)
from repro.core.reshaping import (
    choose_segment_length, matrix_to_tensor, reshape_to_matrix, segment, unsegment,
)
from repro.core.rsvd import randomized_svd

_SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def _matrix(draw, max_l=64, max_m=48):
    l = draw(st.integers(4, max_l))
    m = draw(st.integers(4, max_m))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(l, m)), jnp.float32)


class TestReshapeRoundtrip:
    @given(shape=st.lists(st.integers(1, 8), min_size=1, max_size=4),
           seed=st.integers(0, 2**16))
    @settings(**_SETTINGS)
    def test_tensor_matrix_roundtrip(self, shape, seed):
        rng = np.random.default_rng(seed)
        t = jnp.asarray(rng.normal(size=tuple(shape)), jnp.float32)
        G, orig, l = reshape_to_matrix(t)
        back = matrix_to_tensor(G, orig)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(t))

    @given(n_log=st.integers(2, 10), seed=st.integers(0, 2**16))
    @settings(**_SETTINGS)
    def test_segment_roundtrip(self, n_log, seed):
        n = 2 ** n_log
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
        l = choose_segment_length((n,))
        G = segment(g, l)
        np.testing.assert_array_equal(np.asarray(unsegment(G)), np.asarray(g))

    @given(shape=st.lists(st.integers(2, 12), min_size=2, max_size=3))
    @settings(**_SETTINGS)
    def test_segment_length_divides(self, shape):
        l = choose_segment_length(tuple(shape))
        n = int(np.prod(shape))
        assert n % l == 0 and 1 <= l <= n


class TestCompressionInvariants:
    @given(G=_matrix(), k=st.integers(2, 8))
    @settings(**_SETTINGS)
    def test_projection_never_increases_energy(self, G, k):
        """||M M^T G|| <= ||G|| for any orthonormal M (energy_kept in [0,1])."""
        k = min(k, min(G.shape) - 1)
        st_ = ge.init_compressor(G.shape[0], k, jax.random.PRNGKey(0))
        st_, payload, stats = ge.compress_init(st_, G, k=k)
        assert -1e-4 <= float(stats.energy_kept) <= 1.0 + 1e-4
        assert float(stats.recon_err) <= 1.0 + 1e-4

    @given(G=_matrix(), k=st.integers(2, 6), seed=st.integers(0, 2**16))
    @settings(**_SETTINGS)
    def test_update_round_reconstruction_bounded(self, G, k, seed):
        k = min(k, min(G.shape) - 1)
        d = max(1, k // 2)
        key = jax.random.PRNGKey(seed)
        st_ = ge.init_compressor(G.shape[0], k, key)
        st_, _, _ = ge.compress_init(st_, G, k=k)
        rng = np.random.default_rng(seed)
        G2 = G + jnp.asarray(0.1 * rng.normal(size=G.shape), jnp.float32)
        st_, payload, stats = ge.compress_update(st_, G2, k=k, d=d)
        # Theorem-1 style bound: residual energy <= total energy
        assert float(stats.recon_err) <= 1.0 + 1e-4
        # basis stays orthonormal
        MtM = np.asarray(st_.M.T @ st_.M)
        np.testing.assert_allclose(MtM, np.eye(k), atol=2e-3)


class TestRankPaddedDynamicD:
    """Rank-padded traced-d encode (core/gradestc.compress_step) must equal
    the exact static-d encode for *every* reachable d -- the contract that
    lets Formula 13 run in-jit with zero recompiles (DESIGN.md Sec. 11)."""

    @given(seed=st.integers(0, 2**16), k_log=st.integers(1, 3),
           d_frac=st.floats(0.0, 1.0), drift=st.floats(0.01, 0.5))
    @settings(**_SETTINGS)
    def test_padded_step_equals_static_slice(self, seed, k_log, d_frac, drift):
        from test_gradestc_core import ref_static_slice_update

        k = 2 ** k_log
        l, m = 8 * k, 6 * k
        d = max(1, min(k, int(round(d_frac * k))))
        rng = np.random.default_rng(seed)
        U = np.linalg.qr(rng.normal(size=(l, k)))[0]
        G0 = jnp.asarray(U @ rng.normal(size=(k, m)), jnp.float32)
        U2 = np.linalg.qr(U + drift * rng.normal(size=(l, k)))[0]
        G1 = jnp.asarray(U2 @ rng.normal(size=(k, m))
                         + 0.01 * rng.normal(size=(l, m)), jnp.float32)

        st0 = ge.init_compressor(l, k, jax.random.PRNGKey(seed))
        st1, _, _ = ge.compress_init(st0, G0, k=k)
        st_ref, p_ref, s_ref = ref_static_slice_update(
            st1, G1, k=k, d=d, d_max=k)
        st_pad, p_pad, s_pad = jax.jit(
            lambda st, G, dd: ge.compress_step(st, G, k=k, d=dd, d_max=k)
        )(st1, G1, jnp.asarray(d, jnp.int32))

        np.testing.assert_array_equal(np.asarray(st_pad.M),
                                      np.asarray(st_ref.M))
        np.testing.assert_array_equal(np.asarray(p_pad.coeffs),
                                      np.asarray(p_ref.coeffs))
        assert int(s_pad.d_r) == int(s_ref.d_r)
        nv = np.asarray(p_pad.new_vectors)
        np.testing.assert_array_equal(nv[:d], np.asarray(p_ref.new_vectors))
        if d < k:
            assert np.abs(nv[d:]).max() == 0.0


class TestRSVD:
    @given(seed=st.integers(0, 2**16), k=st.integers(1, 6))
    @settings(**_SETTINGS)
    def test_recovers_exact_low_rank(self, seed, k):
        rng = np.random.default_rng(seed)
        l, m = 48, 32
        A = rng.normal(size=(l, k)) @ rng.normal(size=(k, m))
        U, S, Vt = randomized_svd(jax.random.PRNGKey(seed), jnp.asarray(A, jnp.float32), rank=k)
        recon = np.asarray(U) * np.asarray(S) @ np.asarray(Vt)
        np.testing.assert_allclose(recon, A, atol=1e-2 * np.abs(A).max())

    @given(G=_matrix(), k=st.integers(1, 6))
    @settings(**_SETTINGS)
    def test_singular_values_descending_nonneg(self, G, k):
        k = min(k, min(G.shape))
        _, S, _ = randomized_svd(jax.random.PRNGKey(0), G, rank=k)
        s = np.asarray(S)
        assert (s >= -1e-6).all()
        assert (np.diff(s) <= 1e-5).all()


class TestQuantization:
    @given(seed=st.integers(0, 2**16), bits=st.sampled_from([4, 8]),
           scale=st.floats(0.01, 100.0))
    @settings(**_SETTINGS)
    def test_dequant_error_bound(self, seed, bits, scale):
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(size=(256,)) * scale, jnp.float32)
        codes, s = quantize_stochastic(g, jax.random.PRNGKey(seed), bits)
        gd = dequantize(codes, s, bits)
        step = 2.0 * float(s) / ((1 << bits) - 1)
        assert float(jnp.abs(gd - g).max()) <= step + 1e-5

    @given(seed=st.integers(0, 2**12))
    @settings(max_examples=10, deadline=None)
    def test_stochastic_quant_unbiased(self, seed):
        """E[dequant(quant(g))] == g -- averaged over many keys."""
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
        acc = np.zeros(64)
        n = 200
        for i in range(n):
            codes, s = quantize_stochastic(g, jax.random.PRNGKey(i), 4)
            acc += np.asarray(dequantize(codes, s, 4))
        step = 2.0 * float(s) / 15
        np.testing.assert_allclose(acc / n, np.asarray(g), atol=3 * step / np.sqrt(n) + 1e-2)


class TestTopK:
    @given(seed=st.integers(0, 2**16), k=st.integers(1, 32))
    @settings(**_SETTINGS)
    def test_keeps_largest_and_memory_is_residual(self, seed, k):
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
        st_ = TopKState.init(64)
        st2, ghat, sc = topk_compress(st_, g, k)
        nz = np.flatnonzero(np.asarray(ghat))
        assert len(nz) <= k
        # memory + ghat == corrected signal
        np.testing.assert_allclose(
            np.asarray(st2.memory + ghat), np.asarray(g), atol=1e-6
        )
        # kept entries are the k largest by magnitude
        mags = np.abs(np.asarray(g))
        kept = set(nz.tolist())
        topk = set(np.argsort(-mags)[:k].tolist())
        assert kept <= topk or np.isclose(
            mags[sorted(kept - topk)], sorted(mags[list(topk - kept)])
        ).any() or kept == topk

    @given(seed=st.integers(0, 2**16))
    @settings(**_SETTINGS)
    def test_sign_preserves_sign(self, seed):
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
        ghat, sc = sign_compress(g)
        assert (np.sign(np.asarray(ghat)) == np.sign(np.asarray(g))).all()
