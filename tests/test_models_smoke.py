"""Per-architecture smoke tests (assignment contract): instantiate the
REDUCED variant of each family (<= 2 layers, d_model <= 512, <= 4 experts),
run one forward and one train step on CPU, assert shapes + no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_names, get_config
from repro.models import model

ARCHS = arch_names()


@pytest.fixture(scope="module")
def built():
    """Build each reduced arch once per module."""
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).reduced()
            params = model.init_params(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


def _batch(cfg, B=2, S=16):
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    batch.update(model.extra_inputs(cfg, B, S))
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_reduced_config_contract(name):
    cfg = get_config(name).reduced()
    assert cfg.n_layers <= max(2, len(cfg.pattern))
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_no_nan(name, built):
    cfg, params = built(name)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits = model.forward(cfg, params, batch)
    S_out = S + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab)
    assert not np.isnan(np.asarray(logits)).any()


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step_improves_or_finite(name, built):
    cfg, params = built(name)
    batch = _batch(cfg)
    loss0, grads = jax.value_and_grad(
        lambda p: model.loss_fn(cfg, p, batch)
    )(params)
    assert np.isfinite(float(loss0))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    p2 = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype), params, grads)
    loss1 = float(model.loss_fn(cfg, p2, batch))
    assert np.isfinite(loss1)
    assert loss1 < float(loss0) + 0.5   # step on same batch shouldn't explode


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step_shapes(name, built):
    cfg, params = built(name)
    B = 2
    batch = _batch(cfg, B, 8)
    if cfg.family == "encdec":
        from repro.models import encdec
        enc_out = encdec.encode_audio(cfg, params, batch["audio_frames"])
        cache = model.init_cache(cfg, B, 32, enc_out=enc_out, params=params)
    else:
        cache = model.init_cache(cfg, B, 32)
    lg, cache2 = model.decode_step(cfg, params, cache, batch["tokens"][:, :1])
    assert lg.shape == (B, 1, cfg.vocab)
    assert not np.isnan(np.asarray(lg)).any()
    assert int(cache2.length) == int(cache.length) + 1


@pytest.mark.parametrize("name", [n for n in ARCHS if n not in
                                  ("qwen2-vl-72b",)])  # vlm prefix shifts positions
def test_decode_matches_forward(name, built):
    """KV-cache decode must reproduce the full forward logits."""
    cfg, params = built(name)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no capacity drops
        params = model.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    batch = _batch(cfg, B, S)
    logits = model.forward(cfg, params, batch)[:, -S:, :]
    if cfg.family == "encdec":
        from repro.models import encdec
        enc_out = encdec.encode_audio(cfg, params, batch["audio_frames"])
        cache = model.init_cache(cfg, B, S + 2, enc_out=enc_out, params=params)
    else:
        cache = model.init_cache(cfg, B, S + 2)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(cfg, params, cache, batch["tokens"][:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.abs(logits).max()) + 1e-9
    assert float(jnp.abs(dec - logits).max()) / scale < 2e-2


def test_moe_capacity_drops_are_the_only_decode_divergence():
    """With generous capacity, MoE decode matches training exactly."""
    cfg = dataclasses.replace(get_config("granite-moe-1b-a400m").reduced(),
                              capacity_factor=8.0)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 8)
    logits = model.forward(cfg, params, batch)
    cache = model.init_cache(cfg, 2, 10)
    outs = []
    for t in range(8):
        lg, cache = model.decode_step(cfg, params, cache, batch["tokens"][:, t:t+1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits), atol=1e-3)


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3-1b")
    kinds = cfg.layer_kinds()
    assert kinds.count("global") == 26 // 6 + (1 if 26 % 6 == 0 else 0)
    assert all(k == "global" for i, k in enumerate(kinds) if (i % 6) == 5)


def test_recurrentgemma_pattern_counts():
    cfg = get_config("recurrentgemma-9b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 38
    assert kinds.count("rec") == 26 and kinds.count("local") == 12


def test_chunked_loss_matches_full():
    """ce_chunk must not change the loss value."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 32)
    l_chunked = float(model.loss_fn(cfg, params, batch))
    cfg_full = dataclasses.replace(cfg, ce_chunk=32)
    l_full = float(model.loss_fn(cfg_full, params, batch))
    np.testing.assert_allclose(l_chunked, l_full, rtol=1e-5)


class TestPerfSwitches:
    """SPerf hillclimb switches must preserve semantics (EXPERIMENTS.md)."""

    def test_gqa_native_bit_exact(self):
        cfg0 = get_config("llama3-8b").reduced()
        cfg1 = dataclasses.replace(cfg0, gqa_native=True)
        p = model.init_params(cfg0, jax.random.PRNGKey(0))
        batch = _batch(cfg0, 2, 16)
        l0 = model.forward(cfg0, p, batch)
        l1 = model.forward(cfg1, p, batch)
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))

    def test_moe_stop_gradient_dispatch_semantics(self):
        cfg0 = get_config("granite-moe-1b-a400m").reduced()
        cfg1 = dataclasses.replace(cfg0, moe_stop_gradient_dispatch=True)
        p = model.init_params(cfg0, jax.random.PRNGKey(0))
        batch = _batch(cfg0, 2, 16)
        l0 = float(model.loss_fn(cfg0, p, batch))
        l1 = float(model.loss_fn(cfg1, p, batch))
        assert abs(l0 - l1) < 1e-6
        g0 = jax.grad(lambda pp: model.loss_fn(cfg0, pp, batch))(p)
        g1 = jax.grad(lambda pp: model.loss_fn(cfg1, pp, batch))(p)
        # router gradients identical: the one-hot path carries zero gradient
        np.testing.assert_allclose(
            np.asarray(g0["layers"]["router"]),
            np.asarray(g1["layers"]["router"]), rtol=1e-5, atol=1e-7)

    def test_pad_vocab_shapes_and_loss_masking(self):
        cfg0 = dataclasses.replace(get_config("granite-moe-1b-a400m").reduced(),
                                   vocab=515)
        cfgp = dataclasses.replace(cfg0, pad_vocab_multiple=16)
        p = model.init_params(cfgp, jax.random.PRNGKey(0))
        assert p["embed"].shape[0] == 528            # padded
        batch = _batch(cfgp, 2, 16)
        logits = model.forward(cfgp, p, batch)
        assert logits.shape[-1] == 515               # sliced back
        loss = float(model.loss_fn(cfgp, p, batch))
        assert np.isfinite(loss)
        g = jax.grad(lambda pp: model.loss_fn(cfgp, pp, batch))(p)
        gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0
