"""Unit tests for the GradESTC compressor/decompressor (Algorithms 1-2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gradestc as ge
from repro.core.rsvd import randomized_svd


def _drifting_stream(rng, l, m, k, steps, drift, noise=0.01):
    """Synthetic gradients on a slowly rotating rank-k subspace."""
    U = np.linalg.qr(rng.normal(size=(l, k)))[0]
    for _ in range(steps):
        U = np.linalg.qr(U + drift * rng.normal(size=(l, k)))[0]
        yield jnp.asarray(
            U @ rng.normal(size=(k, m)) + noise * rng.normal(size=(l, m)),
            jnp.float32,
        )


class TestCompressInit:
    def test_basis_orthonormal(self, rng, key):
        l, m, k = 96, 64, 8
        G = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st = ge.init_compressor(l, k, key)
        st, payload, stats = ge.compress_init(st, G, k=k)
        MtM = np.asarray(st.M.T @ st.M)
        np.testing.assert_allclose(MtM, np.eye(k), atol=1e-4)
        assert int(stats.d_r) == k
        assert bool(payload.init)

    def test_init_reconstruction_matches_best_rank_k(self, rng, key):
        """Init compression error should be close to the optimal rank-k error."""
        l, m, k = 128, 96, 8
        # exactly rank-k matrix -> near-zero error
        A = rng.normal(size=(l, k)) @ rng.normal(size=(k, m))
        G = jnp.asarray(A, jnp.float32)
        st = ge.init_compressor(l, k, key)
        st, payload, stats = ge.compress_init(st, G, k=k)
        assert float(stats.recon_err) < 1e-3


class TestCompressUpdate:
    def test_orthonormality_preserved_across_rounds(self, rng, key):
        l, m, k, d = 96, 64, 8, 4
        st = ge.init_compressor(l, k, key)
        for t, G in enumerate(_drifting_stream(rng, l, m, k, 8, 0.05)):
            if t == 0:
                st, payload, stats = ge.compress_init(st, G, k=k)
            else:
                st, payload, stats = ge.compress_update(st, G, k=k, d=d)
            MtM = np.asarray(st.M.T @ st.M)
            np.testing.assert_allclose(MtM, np.eye(k), atol=5e-4)

    def test_error_basis_orthogonal_to_M(self, rng, key):
        """Formula 9: candidates from the fitting error are orthogonal to M."""
        l, m, k, d = 128, 96, 8, 4
        G = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st = ge.init_compressor(l, k, key)
        st, _, _ = ge.compress_init(st, G, k=k)
        M = st.M
        G2 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        A = M.T @ G2
        E = G2 - M @ A
        U, S, Vt = randomized_svd(jax.random.PRNGKey(7), E, rank=d)
        cross = np.asarray(M.T @ U)
        assert np.abs(cross).max() < 1e-3

    def test_low_drift_keeps_basis(self, rng, key):
        """Temporal correlation -> few replacements (the paper's premise)."""
        l, m, k, d = 128, 96, 8, 8
        st = ge.init_compressor(l, k, key)
        total_repl = 0
        for t, G in enumerate(_drifting_stream(rng, l, m, k, 10, 0.002)):
            if t == 0:
                st, _, stats = ge.compress_init(st, G, k=k)
            else:
                st, _, stats = ge.compress_update(st, G, k=k, d=d)
                total_repl += int(stats.d_r)
        assert total_repl <= 2 * 9   # far fewer than k per round

    def test_high_drift_triggers_replacement(self, rng, key):
        l, m, k, d = 128, 96, 8, 8
        st = ge.init_compressor(l, k, key)
        total_repl = 0
        for t, G in enumerate(_drifting_stream(rng, l, m, k, 10, 0.3)):
            if t == 0:
                st, _, stats = ge.compress_init(st, G, k=k)
            else:
                st, _, stats = ge.compress_update(st, G, k=k, d=d)
                total_repl += int(stats.d_r)
        assert total_repl > 9       # replacements happen

    def test_reconstruction_error_bounded_by_projection(self, rng, key):
        """recon_err equals the projection residual: ||G - M M^T G||/||G||."""
        l, m, k, d = 96, 64, 8, 4
        st = ge.init_compressor(l, k, key)
        G0 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st, _, _ = ge.compress_init(st, G0, k=k)
        G1 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st, payload, stats = ge.compress_update(st, G1, k=k, d=d)
        Ghat = np.asarray(st.M @ payload.coeffs)
        err = np.linalg.norm(np.asarray(G1) - Ghat) / np.linalg.norm(np.asarray(G1))
        np.testing.assert_allclose(float(stats.recon_err), err, rtol=1e-3)


class TestDecompressor:
    def test_server_mirrors_client(self, rng, key):
        """Alg. 2: the decompressor basis tracks the compressor exactly."""
        l, m, k, d = 96, 64, 8, 4
        st = ge.init_compressor(l, k, key)
        dec = ge.DecompressorState(M=jnp.zeros((l, k)))
        for t, G in enumerate(_drifting_stream(rng, l, m, k, 6, 0.1)):
            if t == 0:
                st, payload, _ = ge.compress_init(st, G, k=k)
                dec, Ghat = ge.decompress(dec, payload, init_basis=st.M)
            else:
                st, payload, _ = ge.compress_update(st, G, k=k, d=d)
                dec, Ghat = ge.decompress(dec, payload)
            np.testing.assert_allclose(
                np.asarray(dec.M), np.asarray(st.M), atol=1e-6
            )
            np.testing.assert_allclose(
                np.asarray(Ghat), np.asarray(st.M @ payload.coeffs), atol=1e-5
            )

    def test_payload_carries_only_replaced_vectors(self, rng, key):
        l, m, k, d = 96, 64, 8, 4
        st = ge.init_compressor(l, k, key)
        G0 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st, _, _ = ge.compress_init(st, G0, k=k)
        G1 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st, payload, stats = ge.compress_update(st, G1, k=k, d=d)
        d_r = int(stats.d_r)
        nv = np.asarray(payload.new_vectors)
        # slots beyond d_r are zero (never transmitted)
        if d_r < d:
            assert np.abs(nv[d_r:]).max() == 0.0
        assert int(np.asarray(payload.replaced_mask).sum()) == d_r


class TestDynamicD:
    def test_formula13_bucketed(self):
        assert ge.next_candidate_count(0, 32) == 1
        assert ge.next_candidate_count(4, 32) == 8      # ceil(6.2) -> 8
        assert ge.next_candidate_count(30, 32) == 32    # clipped to k
        assert ge.next_candidate_count(10, 32, bucket=False) == 14

    def test_monotone_in_dr(self):
        prev = 0
        for d_r in range(0, 33):
            d = ge.next_candidate_count(d_r, 32)
            assert d >= prev or d == 32
            prev = max(prev, d)


class TestPayloadAccounting:
    def test_formula14(self, rng, key):
        l, m, k, d = 96, 64, 8, 4
        st = ge.init_compressor(l, k, key)
        G0 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st, p0, _ = ge.compress_init(st, G0, k=k)
        assert int(ge.payload_scalars(p0, l=l, m=m, k=k)) == (k * l + k * m) * 4
        G1 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st, p1, s1 = ge.compress_update(st, G1, k=k, d=d)
        d_r = int(s1.d_r)
        expect = (k * m + d_r * l + d_r) * 4
        assert int(ge.payload_scalars(p1, l=l, m=m, k=k)) == expect
