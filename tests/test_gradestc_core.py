"""Unit tests for the GradESTC compressor/decompressor (Algorithms 1-2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gradestc as ge
from repro.core.rsvd import randomized_svd


def _drifting_stream(rng, l, m, k, steps, drift, noise=0.01):
    """Synthetic gradients on a slowly rotating rank-k subspace."""
    U = np.linalg.qr(rng.normal(size=(l, k)))[0]
    for _ in range(steps):
        U = np.linalg.qr(U + drift * rng.normal(size=(l, k)))[0]
        yield jnp.asarray(
            U @ rng.normal(size=(k, m)) + noise * rng.normal(size=(l, m)),
            jnp.float32,
        )


class TestCompressInit:
    def test_basis_orthonormal(self, rng, key):
        l, m, k = 96, 64, 8
        G = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st = ge.init_compressor(l, k, key)
        st, payload, stats = ge.compress_init(st, G, k=k)
        MtM = np.asarray(st.M.T @ st.M)
        np.testing.assert_allclose(MtM, np.eye(k), atol=1e-4)
        assert int(stats.d_r) == k
        assert bool(payload.init)

    def test_init_reconstruction_matches_best_rank_k(self, rng, key):
        """Init compression error should be close to the optimal rank-k error."""
        l, m, k = 128, 96, 8
        # exactly rank-k matrix -> near-zero error
        A = rng.normal(size=(l, k)) @ rng.normal(size=(k, m))
        G = jnp.asarray(A, jnp.float32)
        st = ge.init_compressor(l, k, key)
        st, payload, stats = ge.compress_init(st, G, k=k)
        assert float(stats.recon_err) < 1e-3


class TestCompressUpdate:
    def test_orthonormality_preserved_across_rounds(self, rng, key):
        l, m, k, d = 96, 64, 8, 4
        st = ge.init_compressor(l, k, key)
        for t, G in enumerate(_drifting_stream(rng, l, m, k, 8, 0.05)):
            if t == 0:
                st, payload, stats = ge.compress_init(st, G, k=k)
            else:
                st, payload, stats = ge.compress_update(st, G, k=k, d=d)
            MtM = np.asarray(st.M.T @ st.M)
            np.testing.assert_allclose(MtM, np.eye(k), atol=5e-4)

    def test_error_basis_orthogonal_to_M(self, rng, key):
        """Formula 9: candidates from the fitting error are orthogonal to M."""
        l, m, k, d = 128, 96, 8, 4
        G = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st = ge.init_compressor(l, k, key)
        st, _, _ = ge.compress_init(st, G, k=k)
        M = st.M
        G2 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        A = M.T @ G2
        E = G2 - M @ A
        U, S, Vt = randomized_svd(jax.random.PRNGKey(7), E, rank=d)
        cross = np.asarray(M.T @ U)
        assert np.abs(cross).max() < 1e-3

    def test_low_drift_keeps_basis(self, rng, key):
        """Temporal correlation -> few replacements (the paper's premise)."""
        l, m, k, d = 128, 96, 8, 8
        st = ge.init_compressor(l, k, key)
        total_repl = 0
        for t, G in enumerate(_drifting_stream(rng, l, m, k, 10, 0.002)):
            if t == 0:
                st, _, stats = ge.compress_init(st, G, k=k)
            else:
                st, _, stats = ge.compress_update(st, G, k=k, d=d)
                total_repl += int(stats.d_r)
        assert total_repl <= 2 * 9   # far fewer than k per round

    def test_high_drift_triggers_replacement(self, rng, key):
        l, m, k, d = 128, 96, 8, 8
        st = ge.init_compressor(l, k, key)
        total_repl = 0
        for t, G in enumerate(_drifting_stream(rng, l, m, k, 10, 0.3)):
            if t == 0:
                st, _, stats = ge.compress_init(st, G, k=k)
            else:
                st, _, stats = ge.compress_update(st, G, k=k, d=d)
                total_repl += int(stats.d_r)
        assert total_repl > 9       # replacements happen

    def test_reconstruction_error_bounded_by_projection(self, rng, key):
        """recon_err equals the projection residual: ||G - M M^T G||/||G||."""
        l, m, k, d = 96, 64, 8, 4
        st = ge.init_compressor(l, k, key)
        G0 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st, _, _ = ge.compress_init(st, G0, k=k)
        G1 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st, payload, stats = ge.compress_update(st, G1, k=k, d=d)
        Ghat = np.asarray(st.M @ payload.coeffs)
        err = np.linalg.norm(np.asarray(G1) - Ghat) / np.linalg.norm(np.asarray(G1))
        np.testing.assert_allclose(float(stats.recon_err), err, rtol=1e-3)


class TestDecompressor:
    def test_server_mirrors_client(self, rng, key):
        """Alg. 2: the decompressor basis tracks the compressor exactly."""
        l, m, k, d = 96, 64, 8, 4
        st = ge.init_compressor(l, k, key)
        dec = ge.DecompressorState(M=jnp.zeros((l, k)))
        for t, G in enumerate(_drifting_stream(rng, l, m, k, 6, 0.1)):
            if t == 0:
                st, payload, _ = ge.compress_init(st, G, k=k)
                dec, Ghat = ge.decompress(dec, payload, init_basis=st.M)
            else:
                st, payload, _ = ge.compress_update(st, G, k=k, d=d)
                dec, Ghat = ge.decompress(dec, payload)
            np.testing.assert_allclose(
                np.asarray(dec.M), np.asarray(st.M), atol=1e-6
            )
            np.testing.assert_allclose(
                np.asarray(Ghat), np.asarray(st.M @ payload.coeffs), atol=1e-5
            )

    def test_payload_carries_only_replaced_vectors(self, rng, key):
        l, m, k, d = 96, 64, 8, 4
        st = ge.init_compressor(l, k, key)
        G0 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st, _, _ = ge.compress_init(st, G0, k=k)
        G1 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st, payload, stats = ge.compress_update(st, G1, k=k, d=d)
        d_r = int(stats.d_r)
        nv = np.asarray(payload.new_vectors)
        # slots beyond d_r are zero (never transmitted)
        if d_r < d:
            assert np.abs(nv[d_r:]).max() == 0.0
        assert int(np.asarray(payload.replaced_mask).sum()) == d_r


def ref_static_slice_update(st, G, *, k, d, d_max):
    """The exact-``d`` reference for the rank-padded step: the legacy
    static-``d`` ``compress_update`` with its rSVD widened to ``d_max`` and
    statically sliced back to ``d`` -- i.e. the same candidate pool the
    padded step masks, consumed by the original unpadded replacement logic.
    ``compress_step`` with a *traced* ``d`` must reproduce it exactly."""
    orig = ge.randomized_svd

    def sliced(key, A, rank, *a, **kw):
        U, S, Vt = orig(key, A, rank=d_max, *a, **kw)
        return U[:, :rank], S[:rank], Vt[:rank, :]

    ge.randomized_svd = sliced
    try:
        return ge.compress_update(st, G, k=k, d=d)
    finally:
        ge.randomized_svd = orig


class TestRankPaddedStep:
    """compress_step: traced-d masking over d_max-padded buffers must equal
    static-d slicing, and the unified init path must equal compress_init."""

    L, M_, K = 32, 24, 8

    def _states(self, rng, key, drift=0.2):
        l, m, k = self.L, self.M_, self.K
        G0, G1 = (jnp.asarray(g, jnp.float32)
                  for g in _drifting_stream(rng, l, m, k, 2, drift))
        st0 = ge.init_compressor(l, k, key)
        st1, _, _ = ge.compress_init(st0, G0, k=k)
        return st1, G1

    @pytest.mark.parametrize("d", list(range(1, 9)))
    def test_traced_d_equals_static_slice_for_every_d(self, rng, key, d):
        k = self.K
        st1, G1 = self._states(rng, key)
        st_ref, p_ref, s_ref = ref_static_slice_update(
            st1, G1, k=k, d=d, d_max=k)

        step = jax.jit(lambda st, G, dd: ge.compress_step(
            st, G, k=k, d=dd, d_max=k))
        st_pad, p_pad, s_pad = step(st1, G1, jnp.asarray(d, jnp.int32))

        np.testing.assert_array_equal(np.asarray(st_pad.M),
                                      np.asarray(st_ref.M))
        np.testing.assert_array_equal(np.asarray(p_pad.coeffs),
                                      np.asarray(p_ref.coeffs))
        np.testing.assert_array_equal(np.asarray(p_pad.replaced_mask),
                                      np.asarray(p_ref.replaced_mask))
        assert int(s_pad.d_r) == int(s_ref.d_r)
        # the (d_max, l) wire buffer: first d rows match the exact-d buffer,
        # padded rows beyond d are zero and never charged (Formula 14)
        nv = np.asarray(p_pad.new_vectors)
        np.testing.assert_array_equal(nv[:d], np.asarray(p_ref.new_vectors))
        assert np.abs(nv[d:]).max(initial=0.0) == 0.0

    def test_unified_init_path_matches_compress_init(self, rng, key):
        l, m, k = self.L, self.M_, self.K
        G = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st0 = ge.init_compressor(l, k, key)
        st_a, p_a, s_a = ge.compress_init(st0, G, k=k)
        # d is ignored on the init path (the sketch runs at full capacity)
        st_b, p_b, s_b = ge.compress_step(st0, G, k=k,
                                          d=jnp.asarray(3, jnp.int32))
        np.testing.assert_array_equal(np.asarray(st_a.M), np.asarray(st_b.M))
        np.testing.assert_array_equal(np.asarray(p_a.coeffs),
                                      np.asarray(p_b.coeffs))
        np.testing.assert_array_equal(np.asarray(st_a.key),
                                      np.asarray(st_b.key))
        assert int(s_b.d_r) == k and bool(p_b.init)

    def test_one_compile_serves_every_d(self, rng, key):
        """The whole point: moving d between rounds retraces nothing."""
        k = self.K
        st1, G1 = self._states(rng, key)
        calls = jax.jit(lambda st, G, dd: ge.compress_step(
            st, G, k=k, d=dd, d_max=k))
        for d in (1, 2, 5, 8):
            calls(st1, G1, jnp.asarray(d, jnp.int32))
        assert calls._cache_size() == 1


class TestDynamicD:
    def test_formula13_bucketed(self):
        assert ge.next_candidate_count(0, 32) == 1
        assert ge.next_candidate_count(4, 32) == 8      # ceil(6.2) -> 8
        assert ge.next_candidate_count(30, 32) == 32    # clipped to k
        assert ge.next_candidate_count(10, 32, bucket=False) == 14

    def test_monotone_in_dr(self):
        prev = 0
        for d_r in range(0, 33):
            d = ge.next_candidate_count(d_r, 32)
            assert d >= prev or d == 32
            prev = max(prev, d)

    def test_traced_formula13_matches_unbucketed_host_rule(self):
        """The in-jit rule (what both engines now run every round) is the
        paper's exact Formula 13 -- the host rule without buckets."""
        import jax.numpy as jnp
        for d_r in range(0, 33):
            d_host = ge.next_candidate_count(d_r, 32, bucket=False)
            d_jax = int(ge.next_candidate_count_jax(jnp.asarray(d_r), 32))
            assert d_host == d_jax, d_r


class TestPayloadAccounting:
    def test_formula14(self, rng, key):
        l, m, k, d = 96, 64, 8, 4
        st = ge.init_compressor(l, k, key)
        G0 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st, p0, _ = ge.compress_init(st, G0, k=k)
        assert int(ge.payload_scalars(p0, l=l, m=m, k=k)) == (k * l + k * m) * 4
        G1 = jnp.asarray(rng.normal(size=(l, m)), jnp.float32)
        st, p1, s1 = ge.compress_update(st, G1, k=k, d=d)
        d_r = int(s1.d_r)
        expect = (k * m + d_r * l + d_r) * 4
        assert int(ge.payload_scalars(p1, l=l, m=m, k=k)) == expect
