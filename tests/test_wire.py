"""Wire-format properties: packed-word roundtrips and fused-kernel parity.

Two layers:

  * deterministic parametrized cases -- always run (container and CI) and
    pin the exact acceptance matrix: pack/unpack roundtrip over bit widths
    1-8 with odd tails, every fused wire kernel bit-exact against its
    ``ref.py`` oracle in interpret mode, and the ledger's wire-bit
    accounting identities;
  * a Hypothesis fuzz layer that widens the same checks over random sizes
    and seeds when hypothesis is installed (requirements-dev.txt / CI).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ref as ref
from repro.kernels import ops
from repro.core.codecs import _coeff_wire_bits

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container image has no hypothesis; CI does
    HAVE_HYPOTHESIS = False

_SETTINGS = dict(max_examples=25, deadline=None)


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# bit-pack / unpack roundtrip (the packing primitive is width-agnostic)
# ---------------------------------------------------------------------------

class TestPackRoundtrip:
    @pytest.mark.parametrize("bits", list(range(1, 9)))
    @pytest.mark.parametrize("n", [1, 5, 31, 32, 33, 512, 1000, 4097])
    def test_roundtrip(self, bits, n):
        codes = jnp.asarray(_rng(bits * 131 + n).integers(0, 2 ** bits, n),
                            jnp.uint32)
        words = ref.pack_codes_ref(codes, bits)
        assert words.dtype == jnp.uint32
        back = ref.unpack_codes_ref(words, bits, n)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))

    @pytest.mark.parametrize("bits", [1, 2, 4, 8])
    def test_word_count_is_exact(self, bits):
        # ceil(n * bits / 32) words -- the ledger's bit charge divided by 32,
        # rounded up; no slack word.
        for n in (1, 31, 32, 33, 511, 512, 513):
            codes = jnp.zeros((n,), jnp.uint32)
            cpw = 32 // bits
            assert ref.pack_codes_ref(codes, bits).shape == (-(-n // cpw),)

    def test_max_code_survives(self):
        # the largest biased quantizer code (2*levels = 2**bits - 2) and the
        # all-ones pattern both pack without overflow into neighbours
        for bits in (2, 4, 8):
            codes = jnp.full((97,), 2 ** bits - 1, jnp.uint32)
            back = ref.unpack_codes_ref(ref.pack_codes_ref(codes, bits),
                                        bits, 97)
            np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


# ---------------------------------------------------------------------------
# sign wire (signSGD)
# ---------------------------------------------------------------------------

class TestSignWire:
    @pytest.mark.parametrize("n", [100, 512, 777, 5000, 65536])
    def test_kernel_matches_oracle(self, n):
        g = jnp.asarray(_rng(n).standard_normal(n), jnp.float32)
        wo, so = ops.sign_wire(g, use_kernel=False)
        wk, sk = ops.sign_wire(g, use_kernel=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(wo), np.asarray(wk))
        assert np.asarray(so) == np.asarray(sk)  # bit-exact scale
        ro = ops.sign_unwire(wo, so, n, use_kernel=False)
        rk = ops.sign_unwire(wk, sk, n, use_kernel=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(ro), np.asarray(rk))

    def test_wire_is_one_bit(self):
        n = 777
        g = jnp.asarray(_rng(1).standard_normal(n), jnp.float32)
        words, _ = ops.sign_wire(g, use_kernel=False)
        assert words.shape == (-(-n // 32),) and words.dtype == jnp.uint32

    def test_zero_ships_as_plus_scale(self):
        # 1-bit code book has no zero: bit = (g < 0), so g == 0 -> +scale
        g = jnp.asarray([0.0, -1.0, 2.0, 0.0], jnp.float32)
        w, s = ops.sign_wire(g, use_kernel=False)
        r = np.asarray(ops.sign_unwire(w, s, 4, use_kernel=False))
        sv = float(np.asarray(s))
        np.testing.assert_allclose(r, [sv, -sv, sv, sv], rtol=0)

    def test_parity_under_vmap(self):
        # codecs vmap encode over the client axis; the oracle's pinned
        # reduction (custom_vmap -> lax.map) must still match the kernel
        g = jnp.asarray(_rng(2).standard_normal((3, 1000)), jnp.float32)
        wo, so = jax.vmap(lambda x: ops.sign_wire(x, use_kernel=False))(g)
        wk, sk = jax.vmap(
            lambda x: ops.sign_wire(x, use_kernel=True, interpret=True))(g)
        np.testing.assert_array_equal(np.asarray(wo), np.asarray(wk))
        np.testing.assert_array_equal(np.asarray(so), np.asarray(sk))


# ---------------------------------------------------------------------------
# quantize+pack wire (FedPAQ / FedQClip block path)
# ---------------------------------------------------------------------------

class TestQuantWire:
    @pytest.mark.parametrize("bits", [2, 4, 8])
    @pytest.mark.parametrize("n", [512, 1000, 4096])
    def test_kernel_matches_oracle(self, bits, n):
        g = jnp.asarray(_rng(bits + n).standard_normal(n), jnp.float32)
        key = jax.random.PRNGKey(7)
        wo, so, po = ops.block_quant_wire(g, key, bits=bits, use_kernel=False)
        wk, sk, pk = ops.block_quant_wire(g, key, bits=bits, use_kernel=True,
                                          interpret=True)
        np.testing.assert_array_equal(np.asarray(wo), np.asarray(wk))
        np.testing.assert_array_equal(np.asarray(so), np.asarray(sk))
        do = ops.block_dequant_wire(wo, so, po, bits=bits, use_kernel=False)
        dk = ops.block_dequant_wire(wk, sk, pk, bits=bits, use_kernel=True,
                                    interpret=True)
        np.testing.assert_array_equal(np.asarray(do), np.asarray(dk))
        assert np.isfinite(np.asarray(do)).all()

    @pytest.mark.parametrize("bits", [4, 8])
    def test_packing_is_lossless_on_codes(self, bits):
        # wire words carry the *same* integer codes block_quant_ref emits:
        # quantize -> pack -> unpack -> dequantize == quantize -> dequantize
        n = 1000
        g = jnp.asarray(_rng(9).standard_normal(n), jnp.float32)
        key = jax.random.PRNGKey(5)
        words, scales, pad = ops.block_quant_wire(g, key, bits=bits,
                                                  use_kernel=False)
        via_wire = ops.block_dequant_wire(words, scales, pad, bits=bits,
                                          use_kernel=False)
        gp = jnp.pad(g, (0, int(pad)))
        u = jax.random.uniform(key, gp.shape, jnp.float32)
        codes, scales0 = ref.block_quant_ref(gp, u, ref.WIRE_BLOCK, bits)
        direct = ref.block_dequant_ref(codes, scales0, ref.WIRE_BLOCK,
                                       bits)[:n]
        np.testing.assert_array_equal(np.asarray(via_wire), np.asarray(direct))

    def test_one_bit_is_rejected(self):
        # 2^(bits-1)-1 = 0 levels at bits=1: that wire is ops.sign_wire
        g = jnp.zeros((512,), jnp.float32)
        with pytest.raises(AssertionError):
            ops.block_quant_wire(g, jax.random.PRNGKey(0), bits=1)


# ---------------------------------------------------------------------------
# coefficient wire (GradESTC / SVDFed): f32 / bf16 / int8
# ---------------------------------------------------------------------------

class TestCoeffWire:
    @pytest.mark.parametrize("k,m", [(4, 16), (8, 512), (6, 700)])
    def test_int8_kernel_matches_oracle(self, k, m):
        A = jnp.asarray(_rng(k * m).standard_normal((k, m)), jnp.float32)
        co, so, ho = ops.coeff_quant(A, use_kernel=False)
        ck, sk, hk = ops.coeff_quant(A, use_kernel=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(co), np.asarray(ck))
        np.testing.assert_array_equal(np.asarray(so), np.asarray(sk))
        np.testing.assert_array_equal(np.asarray(ho), np.asarray(hk))
        assert co.dtype == jnp.int8

    @pytest.mark.parametrize("wire_dtype", ["f32", "bf16", "int8"])
    def test_roundtrip_shapes_and_sanity(self, wire_dtype):
        A = jnp.asarray(_rng(3).standard_normal((6, 40)), jnp.float32)
        r = ops.coeff_roundtrip(A, wire_dtype, use_kernel=True,
                                interpret=True)
        assert r.shape == A.shape and r.dtype == A.dtype
        assert np.isfinite(np.asarray(r)).all()
        if wire_dtype == "f32":  # identity wire: bit-exact passthrough
            np.testing.assert_array_equal(np.asarray(r), np.asarray(A))
        elif wire_dtype == "bf16":
            np.testing.assert_array_equal(
                np.asarray(r), np.asarray(A.astype(jnp.bfloat16)
                                          .astype(jnp.float32)))

    def test_int8_codes_bounded_and_deterministic(self):
        A = jnp.asarray(_rng(11).standard_normal((5, 600)) * 30, jnp.float32)
        c1, s1, h1 = ops.coeff_quant(A, use_kernel=False)
        c2, s2, h2 = ops.coeff_quant(A, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
        assert int(np.abs(np.asarray(c1)).max()) <= 127
        # ship == what the server reconstructs from (codes, scales)
        np.testing.assert_array_equal(
            np.asarray(h1), np.asarray(ref.coeff_dequant_ref(c1, s1)))

    def test_bf16_pack_words(self):
        a = jnp.asarray(_rng(13).standard_normal(41), jnp.float32)
        w = ref.bf16_pack_ref(a)
        assert w.dtype == jnp.uint32 and w.size * 2 >= a.size
        back = ref.bf16_unpack_ref(w, a.size)
        np.testing.assert_array_equal(
            np.asarray(back),
            np.asarray(a.astype(jnp.bfloat16).astype(jnp.float32)))


# ---------------------------------------------------------------------------
# fused project -> int8 wire -> residual (SVDFed steady state)
# ---------------------------------------------------------------------------

class TestEncodeQuant:
    @pytest.mark.parametrize("l,k,m", [(128, 8, 512), (256, 16, 700),
                                       (64, 4, 100)])
    def test_kernel_matches_oracle(self, l, k, m):
        rng = _rng(l + m)
        M = jnp.asarray(np.linalg.qr(rng.standard_normal((l, k)))[0],
                        jnp.float32)
        G = jnp.asarray(rng.standard_normal((l, m)), jnp.float32)
        co, so, Eo = ops.encode_quant(M, G, use_kernel=False)
        ck, sk, Ek = ops.encode_quant(M, G, use_kernel=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(co), np.asarray(ck))
        np.testing.assert_array_equal(np.asarray(so), np.asarray(sk))
        np.testing.assert_array_equal(np.asarray(Eo), np.asarray(Ek))
        go = ops.decode_wire(M, co, so, use_kernel=False)
        gk = ops.decode_wire(M, ck, sk, use_kernel=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(go), np.asarray(gk))

    def test_residual_consistent_with_decode(self):
        # E = G - M @ ship and decode(M, codes, scales) = M @ ship:
        # the client residual and the server reconstruction use the SAME
        # dequantized coefficients, so G ~= decode + E up to one GEMM
        rng = _rng(21)
        M = jnp.asarray(np.linalg.qr(rng.standard_normal((128, 8)))[0],
                        jnp.float32)
        G = jnp.asarray(rng.standard_normal((128, 512)), jnp.float32)
        codes, scales, E = ops.encode_quant(M, G, use_kernel=False)
        Ghat = ops.decode_wire(M, codes, scales, use_kernel=False)
        np.testing.assert_allclose(np.asarray(Ghat + E), np.asarray(G),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# ledger accounting identities
# ---------------------------------------------------------------------------

class TestWireBits:
    def test_f32_reproduces_history(self):
        # the default wire must charge exactly the historical 32*k*m bits
        for k, m in ((4, 16), (8, 512), (16, 700)):
            assert _coeff_wire_bits("f32", k, m) == 32 * k * m

    def test_bf16_halves(self):
        assert _coeff_wire_bits("bf16", 8, 512) == 16 * 8 * 512

    def test_int8_charges_codes_plus_scales(self):
        k, m = 8, 700
        nb = -(-m // ref.WIRE_BLOCK)
        assert _coeff_wire_bits("int8", k, m) == 8 * k * m + 32 * k * nb

    def test_ordering(self):
        k, m = 6, 1024
        assert (_coeff_wire_bits("int8", k, m)
                < _coeff_wire_bits("bf16", k, m)
                < _coeff_wire_bits("f32", k, m))


# ---------------------------------------------------------------------------
# Hypothesis fuzz layer (CI: requirements-dev.txt installs hypothesis)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    class TestFuzz:
        @given(bits=st.integers(1, 8), n=st.integers(1, 2048),
               seed=st.integers(0, 2 ** 16))
        @settings(**_SETTINGS)
        def test_pack_roundtrip(self, bits, n, seed):
            codes = jnp.asarray(_rng(seed).integers(0, 2 ** bits, n),
                                jnp.uint32)
            back = ref.unpack_codes_ref(ref.pack_codes_ref(codes, bits),
                                        bits, n)
            np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))

        @given(n=st.integers(1, 4096), seed=st.integers(0, 2 ** 16))
        @settings(**_SETTINGS)
        def test_sign_wire_parity(self, n, seed):
            g = jnp.asarray(_rng(seed).standard_normal(n), jnp.float32)
            wo, so = ops.sign_wire(g, use_kernel=False)
            wk, sk = ops.sign_wire(g, use_kernel=True, interpret=True)
            np.testing.assert_array_equal(np.asarray(wo), np.asarray(wk))
            assert np.asarray(so) == np.asarray(sk)

        @given(bits=st.sampled_from([2, 4, 8]), n=st.integers(1, 2048),
               seed=st.integers(0, 2 ** 16))
        @settings(**_SETTINGS)
        def test_quant_wire_parity(self, bits, n, seed):
            g = jnp.asarray(_rng(seed).standard_normal(n), jnp.float32)
            key = jax.random.PRNGKey(seed)
            wo, so, po = ops.block_quant_wire(g, key, bits=bits,
                                              use_kernel=False)
            wk, sk, pk = ops.block_quant_wire(g, key, bits=bits,
                                              use_kernel=True, interpret=True)
            np.testing.assert_array_equal(np.asarray(wo), np.asarray(wk))
            do = ops.block_dequant_wire(wo, so, po, bits=bits,
                                        use_kernel=False)
            dk = ops.block_dequant_wire(wk, sk, pk, bits=bits,
                                        use_kernel=True, interpret=True)
            np.testing.assert_array_equal(np.asarray(do), np.asarray(dk))

        @given(k=st.integers(1, 12), m=st.integers(1, 800),
               seed=st.integers(0, 2 ** 16))
        @settings(**_SETTINGS)
        def test_coeff_wire_parity(self, k, m, seed):
            A = jnp.asarray(_rng(seed).standard_normal((k, m)), jnp.float32)
            co, so, ho = ops.coeff_quant(A, use_kernel=False)
            ck, sk, hk = ops.coeff_quant(A, use_kernel=True, interpret=True)
            np.testing.assert_array_equal(np.asarray(co), np.asarray(ck))
            np.testing.assert_array_equal(np.asarray(ho), np.asarray(hk))
