"""Paper Figure 9 / Section V-D: sensitivity to the basis count k.

Sweeps a uniform k over all compressed groups and reports uplink/accuracy --
the paper's finding: small k slows convergence, large k wastes uplink, a
broad middle plateau is insensitive.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.fl import FLConfig, run_fl
from repro.fl.simulation import default_tiny_arch
from repro.models import param_group_shapes
from repro.core.policy import make_policy


def run(rounds: int = 12, ks=(4, 8, 16, 32), seed: int = 0) -> List[Dict]:
    arch = default_tiny_arch()
    groups = param_group_shapes(arch)
    rows = []
    for k in ks:
        # uniform-k overrides for every group the default policy compresses
        base = make_policy(groups, min_params=4096)
        overrides = {
            name: (min(k, plan.l // 2, plan.m // 2), plan.l)
            for name, plan in base.plans.items() if plan.compress
        }
        cfg = FLConfig(
            method="gradestc", rounds=rounds, n_clients=4, local_steps=2,
            batch=8, seq=48, seed=seed, eval_every=max(1, rounds // 6),
            policy_overrides=overrides, min_params=4096,
        )
        res = run_fl(cfg)
        rows.append({
            "table": "fig9",
            "k": k,
            "best_loss": round(min(res.eval_loss), 4),
            "best_acc": round(max(res.eval_acc), 4),
            "total_uplink_mb": round(res.ledger.uplink_total / 2**20, 3),
            "sum_d": res.extra.get("sum_d", ""),
        })
    return rows


HEADER = ["table", "k", "best_loss", "best_acc", "total_uplink_mb", "sum_d"]
