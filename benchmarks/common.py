"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import csv
import io
import sys
import time
from typing import Dict, Iterable, List


def emit_csv(rows: List[Dict], header: Iterable[str], file=None) -> None:
    w = csv.DictWriter(file or sys.stdout, fieldnames=list(header),
                       extrasaction="ignore")
    w.writeheader()
    for r in rows:
        w.writerow(r)


def timer(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall us per call (post-warmup, blocked on device results)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
