"""Kernel microbenchmarks: Pallas (interpret on CPU) vs pure-jnp oracle.

On this CPU container the interesting number is the ORACLE (XLA) path --
interpret-mode Pallas timing is a Python emulation, reported only for
completeness.  On TPU the same harness times the compiled kernels.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import timer


def run() -> List[Dict]:
    rows = []
    key = jax.random.PRNGKey(0)
    on_tpu = jax.default_backend() == "tpu"
    for (l, k, m) in [(1024, 32, 1024), (4096, 64, 4096)]:
        M = jnp.linalg.qr(jax.random.normal(key, (l, k)))[0]
        G = jax.random.normal(key, (l, m))
        ref_encode = jax.jit(lambda M, G: ref.encode_ref(M, G))
        us_ref = timer(ref_encode, M, G)
        row = {
            "table": "kernel", "kernel": "encode", "shape": f"l{l}_k{k}_m{m}",
            "us_ref_xla": round(us_ref, 1),
        }
        if on_tpu:
            us_k = timer(lambda M, G: ops.encode(M, G), M, G)
            row["us_pallas"] = round(us_k, 1)
        rows.append(row)

        A = M.T @ G
        ref_decode = jax.jit(lambda M, A: ref.decode_ref(M, A))
        rows.append({
            "table": "kernel", "kernel": "decode", "shape": f"l{l}_k{k}_m{m}",
            "us_ref_xla": round(timer(ref_decode, M, A), 1),
        })

    g = jax.random.normal(key, (1 << 20,))
    q = jax.jit(lambda g, k: ops.block_quantize(g, k, use_kernel=False))
    rows.append({
        "table": "kernel", "kernel": "block_quant_1M", "shape": "n1048576",
        "us_ref_xla": round(timer(q, g, key), 1),
    })
    return rows


HEADER = ["table", "kernel", "shape", "us_ref_xla", "us_pallas"]
