"""Kernel microbenchmarks: fused wire kernels vs split stages, per backend.

Reports bytes/s per kernel over the raw gradient payload (input f32 bytes),
for three paths:

  oracle_xla        jit'd pure-jnp oracle (ref.py)        -- comparable
  fused_xla         jit'd fused dispatcher, kernel off    -- comparable
  split_xla         the same work as two jit'd stages
                    (quantize, then pack) with a real
                    dispatch boundary between them        -- comparable
  pallas_interpret  interpret-mode Pallas (a Python
                    emulation of the TPU kernel)          -- NOT comparable
  pallas_tpu        compiled Pallas kernel                -- comparable

Interpret-mode rows carry ``comparable: false`` so downstream tooling never
reads the emulation as a perf result.  Select paths with ``--backend``:
``auto`` (default) runs the XLA paths plus pallas_tpu on TPU or
pallas_interpret elsewhere; ``xla`` / ``interpret`` / ``tpu`` force one.

CLI:  PYTHONPATH=src python -m benchmarks.kernel_micro \
          [--backend auto|xla|interpret|tpu] [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import functools
import json
import sys
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import timer

BACKENDS = ("auto", "xla", "interpret", "tpu")


def _paths(backend: str) -> List[str]:
    on_tpu = jax.default_backend() == "tpu"
    if backend == "auto":
        return ["xla", "tpu" if on_tpu else "interpret"]
    if backend == "tpu" and not on_tpu:
        raise SystemExit("--backend tpu: no TPU in this process")
    return [backend]


def _row(kernel: str, shape: str, path: str, us: float, nbytes: int,
         fused: bool) -> Dict:
    comparable = path != "pallas_interpret"
    r = {
        "table": "kernel", "kernel": kernel, "shape": shape, "path": path,
        "fused": fused, "us": round(us, 1), "bytes": nbytes,
        "gbps": round(nbytes / us * 1e6 / 1e9, 3) if comparable else None,
        "comparable": comparable,
    }
    return r


def _bench_sign(n: int, paths: List[str], rows: List[Dict]) -> None:
    g = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
    nbytes = n * 4
    shape = f"n{n}"
    if "xla" in paths:
        fused = jax.jit(lambda g: ops.sign_wire(g, use_kernel=False))
        rows.append(_row("sign_wire", shape, "fused_xla",
                         timer(fused, g), nbytes, True))
        # split: sign bits materialized f32-wide, packed in a second dispatch
        s1 = jax.jit(lambda g: ((g < 0).astype(jnp.uint32),
                                ref.mean_abs_ref(g)))
        s2 = jax.jit(lambda b: ref.pack_codes_ref(b, 1))
        rows.append(_row("sign_wire", shape, "split_xla",
                         timer(lambda g: s2(s1(g)[0]), g), nbytes, False))
    for p in ("interpret", "tpu"):
        if p in paths:
            k = jax.jit(functools.partial(ops.sign_wire, use_kernel=True,
                                          interpret=(p == "interpret")))
            rows.append(_row("sign_wire", shape, f"pallas_{p}",
                             timer(k, g), nbytes, True))


def _bench_quant(n: int, bits: int, paths: List[str],
                 rows: List[Dict]) -> None:
    g = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
    key = jax.random.PRNGKey(2)
    nbytes = n * 4
    shape = f"n{n}_b{bits}"
    if "xla" in paths:
        fused = jax.jit(functools.partial(
            ops.block_quant_wire, bits=bits, use_kernel=False))
        rows.append(_row("quant_wire", shape, "fused_xla",
                         timer(fused, g, key), nbytes, True))
        s1 = jax.jit(functools.partial(ops.block_quantize, bits=bits,
                                       use_kernel=False))

        def _split_pack(codes, bits=bits):
            levels = 2 ** (bits - 1) - 1
            return ref.pack_codes_ref(
                (codes.astype(jnp.int32) + levels).astype(jnp.uint32), bits)

        s2 = jax.jit(_split_pack)
        rows.append(_row("quant_wire", shape, "split_xla",
                         timer(lambda g, k: s2(s1(g, k)[0]), g, key),
                         nbytes, False))
    for p in ("interpret", "tpu"):
        if p in paths:
            k = jax.jit(functools.partial(
                ops.block_quant_wire, bits=bits, use_kernel=True,
                interpret=(p == "interpret")))
            rows.append(_row("quant_wire", shape, f"pallas_{p}",
                             timer(k, g, key), nbytes, True))


def _bench_encode_quant(l: int, k: int, m: int, paths: List[str],
                        rows: List[Dict]) -> None:
    key = jax.random.PRNGKey(3)
    M = jnp.linalg.qr(jax.random.normal(key, (l, k)))[0].astype(jnp.float32)
    G = jax.random.normal(key, (l, m), jnp.float32)
    nbytes = l * m * 4
    shape = f"l{l}_k{k}_m{m}"
    if "xla" in paths:
        fused = jax.jit(functools.partial(ops.encode_quant,
                                          use_kernel=False))
        rows.append(_row("encode_quant", shape, "fused_xla",
                         timer(fused, M, G), nbytes, True))
        # split: full-precision A and E materialized, then quantized
        s1 = jax.jit(lambda M, G: ref.encode_ref(M, G))
        s2 = jax.jit(ref.coeff_quant_ref)
        rows.append(_row("encode_quant", shape, "split_xla",
                         timer(lambda M, G: s2(s1(M, G)[0]), M, G),
                         nbytes, False))
    for p in ("interpret", "tpu"):
        if p in paths:
            kk = jax.jit(functools.partial(ops.encode_quant, use_kernel=True,
                                           interpret=(p == "interpret")))
            rows.append(_row("encode_quant", shape, f"pallas_{p}",
                             timer(kk, M, G), nbytes, True))


def _bench_decode_wire(l: int, k: int, m: int, paths: List[str],
                       rows: List[Dict]) -> None:
    key = jax.random.PRNGKey(4)
    M = jnp.linalg.qr(jax.random.normal(key, (l, k)))[0].astype(jnp.float32)
    A = jax.random.normal(key, (k, m), jnp.float32)
    codes, scales, _ = ops.coeff_quant(A, use_kernel=False)
    nbytes = l * m * 4
    shape = f"l{l}_k{k}_m{m}"
    if "xla" in paths:
        fused = jax.jit(functools.partial(ops.decode_wire, use_kernel=False))
        rows.append(_row("decode_wire", shape, "fused_xla",
                         timer(fused, M, codes, scales), nbytes, True))
        s1 = jax.jit(ref.coeff_dequant_ref)
        s2 = jax.jit(lambda M, A: ref.decode_ref(M, A))
        rows.append(_row("decode_wire", shape, "split_xla",
                         timer(lambda M, c, s: s2(M, s1(c, s)),
                               M, codes, scales), nbytes, False))
    for p in ("interpret", "tpu"):
        if p in paths:
            kk = jax.jit(functools.partial(ops.decode_wire, use_kernel=True,
                                           interpret=(p == "interpret")))
            rows.append(_row("decode_wire", shape, f"pallas_{p}",
                             timer(kk, M, codes, scales), nbytes, True))


def run(backend: str = "auto", smoke: bool = False) -> List[Dict]:
    paths = _paths(backend)
    rows: List[Dict] = []
    n = 1 << 16 if smoke else 1 << 20
    _bench_sign(n, paths, rows)
    for bits in ((8,) if smoke else (4, 8)):
        _bench_quant(n, bits, paths, rows)
    lkm = (256, 16, 512) if smoke else (1024, 32, 4096)
    _bench_encode_quant(*lkm, paths, rows)
    _bench_decode_wire(*lkm, paths, rows)
    return rows


def to_report(rows: List[Dict], backend: str) -> Dict:
    """BENCH_kernels.json payload: rows plus provenance."""
    return {
        "benchmark": "kernel_micro",
        "backend_arg": backend,
        "device": jax.default_backend(),
        "note": ("rows with comparable=false are interpret-mode Pallas "
                 "(Python emulation) -- correctness probes, never perf"),
        "results": rows,
    }


HEADER = ["table", "kernel", "shape", "path", "fused", "us", "bytes",
          "gbps", "comparable"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", choices=BACKENDS, default="auto")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write BENCH_kernels.json-style report")
    args = ap.parse_args(argv)
    rows = run(backend=args.backend, smoke=args.smoke)
    from .common import emit_csv

    emit_csv(rows, HEADER)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(to_report(rows, args.backend), f, indent=2)
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
