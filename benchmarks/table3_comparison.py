"""Paper Table III: uplink at accuracy threshold / total uplink / best
accuracy, per method x data distribution.

The paper's datasets (MNIST/CIFAR) are replaced by the synthetic LM task
(DESIGN.md "Assumptions changed"); the comparison structure -- FedAvg, Top-k,
FedPAQ, SVDFed, FedQClip, GradESTC under IID and Dirichlet(0.5/0.1) -- is
identical.  The threshold is the loss FedAvg reaches at 60% of training.
"""

from __future__ import annotations

from typing import Dict, List

from repro.fl import FLConfig, run_fl

METHODS = ["fedavg", "topk", "fedpaq", "fedqclip", "svdfed", "gradestc"]
DISTS = [("iid", None), ("dir0.5", 0.5), ("dir0.1", 0.1)]


def run(rounds: int = 15, n_clients: int = 6, seed: int = 0) -> List[Dict]:
    rows = []
    for dist_name, alpha in DISTS:
        # FedAvg first: defines the accuracy threshold for this distribution
        results = {}
        for method in METHODS:
            cfg = FLConfig(
                method=method, rounds=rounds, n_clients=n_clients,
                local_steps=2, batch=8, seq=48, alpha=alpha, seed=seed,
                eval_every=max(1, rounds // 6),
            )
            results[method] = run_fl(cfg)
        fedavg = results["fedavg"]
        thr_idx = max(0, int(len(fedavg.eval_loss) * 0.6) - 1)
        threshold = fedavg.eval_loss[thr_idx]
        for method in METHODS:
            res = results[method]
            at_thr = res.uplink_at_loss(threshold)
            rows.append({
                "table": "table3",
                "dist": dist_name,
                "method": method,
                "uplink_at_threshold_mb": (
                    round(at_thr / 2**20, 3) if at_thr is not None else ""
                ),
                "total_uplink_mb": round(res.ledger.uplink_total / 2**20, 3),
                "best_loss": round(min(res.eval_loss), 4),
                "best_acc": round(max(res.eval_acc), 4),
                "wall_s": round(res.wall_s, 1),
            })
    return rows


HEADER = ["table", "dist", "method", "uplink_at_threshold_mb",
          "total_uplink_mb", "best_loss", "best_acc", "wall_s"]
