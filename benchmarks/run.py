"""Benchmark harness -- one module per paper table/figure.

  table3   Table III  method comparison across data distributions
  table4   Table IV   GradESTC ablation (-first/-all/-k/full/+ef)
  fig1     Figure 1/2 temporal gradient correlation + parameter sizes
  fig9     Figure 9   k sensitivity
  kernel   --         wire-codec kernel microbenchmarks (BENCH_kernels.json)
  roofline Sec 4/5    dry-run roofline table (reads reports/dryrun.json)

Usage:
  PYTHONPATH=src python -m benchmarks.run [--only table3,fig1] [--rounds N]
  PYTHONPATH=src python -m benchmarks.run --only kernel --smoke

Prints ``name,...`` CSV blocks per benchmark.  The kernel benchmark also
writes ``BENCH_kernels.json`` (bytes/s per kernel, fused vs split stages,
oracle-XLA vs Pallas; interpret-mode rows are flagged non-comparable) so the
kernel layer has a tracked perf trajectory; ``--smoke`` shrinks shapes for
CI.
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma list of {table3,table4,fig1,fig9,kernel,roofline}")
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / fast path (kernel benchmark)")
    ap.add_argument("--backend", default="auto",
                    help="kernel benchmark backend: auto|xla|interpret|tpu")
    ap.add_argument("--kernels-json", default="BENCH_kernels.json",
                    metavar="PATH", help="kernel benchmark report path")
    args = ap.parse_args(argv)
    want = set(args.only.split(",")) if args.only else {
        "table3", "table4", "fig1", "fig9", "kernel", "roofline"}

    from .common import emit_csv

    t0 = time.time()
    if "table3" in want:
        from . import table3_comparison as t3
        print("# Table III -- method comparison", flush=True)
        emit_csv(t3.run(rounds=args.rounds), t3.HEADER)
    if "table4" in want:
        from . import table4_ablation as t4
        print("# Table IV -- ablation", flush=True)
        emit_csv(t4.run(rounds=args.rounds), t4.HEADER)
    if "fig1" in want:
        from . import fig1_temporal as f1
        print("# Figure 1/2 -- temporal correlation", flush=True)
        rows = f1.run(rounds=args.rounds)
        emit_csv(f1.adjacent_summary(rows), f1.HEADER_ADJ)
    if "fig9" in want:
        from . import fig9_k_sensitivity as f9
        print("# Figure 9 -- k sensitivity", flush=True)
        emit_csv(f9.run(rounds=args.rounds), f9.HEADER)
    if "kernel" in want:
        from . import kernel_micro as km
        print("# Kernel microbenchmarks (wire layer)", flush=True)
        rows = km.run(backend=args.backend, smoke=args.smoke)
        emit_csv(rows, km.HEADER)
        with open(args.kernels_json, "w") as f:
            json.dump(km.to_report(rows, args.backend), f, indent=2)
        print(f"# wrote {args.kernels_json}", flush=True)
    if "roofline" in want:
        from . import roofline as rl
        print("# Roofline (from dry-run)", flush=True)
        emit_csv(rl.run(), rl.HEADER)
    print(f"# total wall: {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
