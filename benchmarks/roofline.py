"""Roofline report: renders reports/dryrun.json (written by
``python -m repro.launch.dryrun``) into the EXPERIMENTS.md Sec-Roofline table.

Reports, per (arch x shape): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS / HLO_FLOPS (useful-compute ratio), and per-device
memory.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

REPORT = os.environ.get("DRYRUN_REPORT", "reports/dryrun.json")


def run(report_path: str = REPORT) -> List[Dict]:
    if not os.path.exists(report_path):
        return [{
            "table": "roofline", "arch": "(run repro.launch.dryrun first)",
            "shape": "", "status": f"missing {report_path}",
        }]
    with open(report_path) as f:
        recs = json.load(f)
    rows = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        row = {
            "table": "roofline",
            "arch": r["arch"],
            "shape": r["shape"],
            "method": r.get("method", "-"),
            "mesh": "2pod" if r.get("multi_pod") else "1pod",
            "status": r["status"],
        }
        if r["status"] == "ok":
            row.update({
                "peak_gib": round(r["memory"]["peak_bytes_tpu"] / 2**30, 2)
                if "peak_bytes_tpu" in r.get("memory", {})
                else round(r["memory"]["peak_bytes"] / 2**30, 2),
                "fits": r.get("fits_hbm"),
            })
            if "roofline" in r:
                rl = r["roofline"]
                row.update({
                    "compute_ms": round(rl["compute_s"] * 1e3, 2),
                    "memory_ms": round(rl["memory_s"] * 1e3, 2),
                    "collective_ms": round(rl["collective_s"] * 1e3, 2),
                    "bottleneck": rl["bottleneck"],
                    "useful_ratio": round(r.get("useful_ratio", 0), 3),
                })
        elif r["status"] == "skipped":
            row["status"] = f"skipped: {r['skip_reason'][:40]}"
        rows.append(row)
    return rows


HEADER = ["table", "arch", "shape", "method", "mesh", "status", "peak_gib",
          "fits", "compute_ms", "memory_ms", "collective_ms", "bottleneck",
          "useful_ratio"]
