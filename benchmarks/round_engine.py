"""Round-engine benchmark: fused single-program round vs per-client loop,
per *method* (the codec protocol runs every Table III method fused), plus
the device-count sweep for the sharded round (DESIGN.md Sec. 10).

Measures, for each method at the configured client counts on the current
backend:

  * steady-state rounds/sec per engine -- the median per-round wall time
    *after* the warmup rounds, reported separately from the first round
    (which is dominated by XLA trace+compile time; mixing it into the mean
    would swamp the per-method steady-state comparison);
  * measured host syncs per round (every device->host fetch in the FL
    runtime goes through ``core.metrics.host_fetch``; round accounting
    contracts to exactly 1 -- the packed stats vector -- with eval-round
    fetches counted separately via ``FLResult.eval_rounds``);
  * the fused-over-loop steady-state speedup.

The **device sweep** additionally runs the fused engine sharded over
1/4/8 host-platform devices (each count in its own subprocess, forcing
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax imports)
and reports per-count round wall, speedup over 1 device, scaling
efficiency (speedup/N), and the pipeline overlap won by the speculative
deferred-stats host loop (``speculate`` on vs off).  ``host_cores`` is
recorded alongside: on machines with fewer physical cores than devices the
sweep measures oversubscribed lockstep, not real scaling.

The model is deliberately tiny: the engines run *identical* math, so at
equal compute the ratio isolates per-client dispatch overhead, which is
what dominates FL simulation at the 100+ client scale of the paper's
comparisons.

Emits ``BENCH_round_engine.json`` (committed at the repo root so the perf
trajectory is tracked PR-over-PR).

Usage:  PYTHONPATH=src python benchmarks/round_engine.py \
            [--out PATH] [--clients C ...] [--methods M ...] \
            [--device-sweep N ...] [--smoke]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import tempfile

import jax
import numpy as np

from repro.core import metrics
from repro.fl import FLConfig, run_fl
from repro.models.config import ArchConfig

#: every method is benchmarked at this client count (the acceptance bar:
#: >= 2x fused-over-loop for the baselines at 50 clients on CPU) ...
METHOD_CLIENTS = 50
#: ... and GradESTC additionally sweeps the scaling curve.
GRADESTC_CLIENTS = (10, 50, 100)
METHODS = ("gradestc", "topk", "fedpaq", "signsgd", "fedqclip", "svdfed")
#: the sharded-round device sweep (fused engine only).  1/4/8 are the
#: acceptance points; 2 is included because this matters on small hosts:
#: scaling saturates at the physical core count (``host_cores`` rides in
#: the payload), and on a 2-core container the 2-device point is the only
#: one measuring real parallelism rather than oversubscribed lockstep.
DEVICE_SWEEP = (1, 2, 4, 8)
SWEEP_METHODS = ("gradestc", "fedpaq")
WARMUP_ROUNDS = 4          # covers init round + Formula-13 d re-bucketing compiles
MEASURED_ROUNDS = 8


def bench_arch() -> ArchConfig:
    """Dispatch-bound regime: real transformer, minimal per-client compute."""
    return ArchConfig(
        name="fl-bench", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=128, vocab=64, dtype="float32", remat=False,
        attn_chunk=0,
    )


def bench_cfg(method: str, engine: str, n_clients: int, *, devices: int = 1,
              speculate: bool = True, rounds: int | None = None) -> FLConfig:
    return FLConfig(
        method=method,
        rounds=WARMUP_ROUNDS + MEASURED_ROUNDS if rounds is None else rounds,
        n_clients=n_clients, local_steps=1, batch=1, seq=8,
        eval_every=10 ** 9, seed=0, arch=bench_arch(), engine=engine,
        devices=devices, speculate=speculate,
    )


def measure(method: str, engine: str, n_clients: int, *, devices: int = 1,
            speculate: bool = True, rounds: int | None = None) -> dict:
    cfg = bench_cfg(method, engine, n_clients, devices=devices,
                    speculate=speculate, rounds=rounds)
    warm = min(WARMUP_ROUNDS, cfg.rounds - 1)
    metrics.reset_host_sync_count()
    res = run_fl(cfg)
    syncs = metrics.host_sync_count()
    wall = res.extra["round_wall_s"]
    steady = float(np.median(wall[warm:]))
    return {
        "engine": res.extra["engine"],
        "method": method,
        "n_clients": n_clients,
        "devices": devices,
        "speculate": speculate,
        # steady state and trace/compile cost reported separately: round 0
        # is dominated by compilation and would otherwise skew any mean.
        "steady_round_ms": steady * 1e3,
        "first_round_ms": wall[0] * 1e3,
        "rounds_per_sec": 1.0 / steady,
        # round accounting syncs only; eval rounds fetch once each and are
        # excluded so the contract stays "exactly 1 per round".
        "host_syncs_per_round": (syncs - len(res.eval_rounds)) / cfg.rounds,
        "spec_misses": res.extra.get("spec_misses", 0),
        "warmup_rounds": warm,
        "measured_rounds": cfg.rounds - warm,
        "total_wall_s": res.wall_s,
        "final_eval_loss": res.eval_loss[-1],
        "uplink_total_bytes": res.ledger.uplink_total,
    }


# ---------------------------------------------------------------------------
# device sweep: one subprocess per device count (XLA fixes the host device
# count at first jax import, so each count needs a fresh process)
# ---------------------------------------------------------------------------

def run_child(devices: int, methods, clients: int, rounds: int | None,
              out: pathlib.Path) -> dict:
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={devices}".strip())
    cmd = [sys.executable, str(pathlib.Path(__file__).resolve()), "--child",
           "--devices", str(devices), "--clients", str(clients),
           "--methods", *methods, "--out", str(out)]
    if rounds is not None:
        cmd += ["--rounds", str(rounds)]
    subprocess.run(cmd, check=True, env=env)
    return json.loads(out.read_text())


def child_main(args) -> int:
    clients = args.clients[0] if args.clients else METHOD_CLIENTS
    results = []
    for method in args.methods:
        for speculate in (True, False):
            results.append(measure(method, "fused", clients,
                                   devices=args.devices, speculate=speculate,
                                   rounds=args.rounds))
    pathlib.Path(args.out).write_text(json.dumps(results))
    return 0


def device_sweep(sweep, methods, clients: int, rounds: int | None) -> dict:
    if jax.default_backend() != "cpu":
        print("device sweep: skipping (forced host devices are CPU-only)")
        return {}
    rows = []
    for n in sweep:
        with tempfile.NamedTemporaryFile(suffix=".json") as tmp:
            rows += run_child(n, methods, clients, rounds,
                              pathlib.Path(tmp.name))
        for r in rows[-2 * len(methods):]:
            tag = "spec" if r["speculate"] else "nospec"
            print(f"  sweep {r['method']:10s} devices={n} [{tag:6s}] "
                  f"{r['steady_round_ms']:7.1f} ms/round "
                  f"({r['host_syncs_per_round']:.1f} syncs, "
                  f"{r['spec_misses']} misses)")
    base = {(r["method"]): r["steady_round_ms"] for r in rows
            if r["devices"] == sweep[0] and r["speculate"]}
    speedup, efficiency, overlap = {}, {}, {}
    for r in rows:
        m, n = r["method"], r["devices"]
        if r["speculate"]:
            sp = base[m] / r["steady_round_ms"]
            speedup.setdefault(m, {})[str(n)] = sp
            efficiency.setdefault(m, {})[str(n)] = sp / (n / sweep[0])
        else:
            on = next(x for x in rows if x["method"] == m
                      and x["devices"] == n and x["speculate"])
            overlap.setdefault(m, {})[str(n)] = (
                r["steady_round_ms"] / on["steady_round_ms"])
    return {
        "clients": clients,
        "methods": list(methods),
        "device_counts": list(sweep),
        "host_cores": os.cpu_count(),
        "results": rows,
        "speedup_vs_first": speedup,
        "scaling_efficiency": efficiency,
        # >1 means the speculative deferred-stats pipeline beats the
        # blocking (speculate=False) host loop at that device count.
        "pipeline_overlap": overlap,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=str(pathlib.Path(__file__).resolve()
                                         .parent.parent / "BENCH_round_engine.json"))
    ap.add_argument("--clients", type=int, nargs="*", default=None,
                    help="override client counts (applied to every method)")
    ap.add_argument("--methods", nargs="*", default=list(METHODS))
    ap.add_argument("--device-sweep", type=int, nargs="*",
                    default=list(DEVICE_SWEEP),
                    help="device counts for the sharded sweep ([] disables)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 1 method, 5 rounds, devices 1+2, "
                    "no loop-engine grid")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--devices", type=int, default=1, help=argparse.SUPPRESS)
    ap.add_argument("--rounds", type=int, default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.child:
        return child_main(args)

    sweep_rounds = None
    sweep = args.device_sweep
    # the sweep honors --methods: sweep only the requested subset of the
    # sweep-able methods, and skip it entirely if none was requested
    sweep_methods = [m for m in args.methods if m in SWEEP_METHODS]
    if not sweep_methods:
        sweep = []
    sweep_clients = (args.clients[0] if args.clients else METHOD_CLIENTS)
    if args.smoke:
        args.methods = ["gradestc"]
        sweep_methods = ["gradestc"]
        sweep = [1, 2]
        sweep_rounds = 5
        sweep_clients = 8

    results = []
    speedups: dict = {}
    if not args.smoke:
        grid = []
        for method in args.methods:
            counts = (args.clients if args.clients
                      else GRADESTC_CLIENTS if method == "gradestc"
                      else (METHOD_CLIENTS,))
            grid += [(method, C) for C in counts]
        for method, C in grid:
            loop = measure(method, "loop", C)
            fused = measure(method, "fused", C)
            results += [loop, fused]
            sp = loop["steady_round_ms"] / fused["steady_round_ms"]
            speedups.setdefault(method, {})[str(C)] = sp
            print(f"{method:10s} n_clients={C:4d}  "
                  f"loop {loop['steady_round_ms']:8.1f} ms/round "
                  f"({loop['host_syncs_per_round']:.1f} syncs)   "
                  f"fused {fused['steady_round_ms']:8.1f} ms/round "
                  f"({fused['host_syncs_per_round']:.1f} syncs)   "
                  f"speedup {sp:.2f}x   "
                  f"[first round: loop {loop['first_round_ms']:.0f} ms, "
                  f"fused {fused['first_round_ms']:.0f} ms]")

    sweep_payload = (device_sweep(sweep, sweep_methods, sweep_clients,
                                  sweep_rounds) if sweep else {})

    payload = {
        "benchmark": "round_engine",
        "backend": jax.default_backend(),
        "device": str(jax.devices()[0]),
        "arch": dataclasses.asdict(bench_arch()),
        "config": {"local_steps": 1, "batch": 1, "seq": 8,
                   "methods": args.methods},
        "results": results,
        "speedup_fused_over_loop": speedups,
        "device_sweep": sweep_payload,
    }
    pathlib.Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
