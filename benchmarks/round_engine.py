"""Round-engine benchmark: fused single-program round vs per-client loop,
per *method* (the codec protocol runs every Table III method fused).

Measures, for each method at the configured client counts on the current
backend:

  * steady-state rounds/sec per engine -- the median per-round wall time
    *after* the warmup rounds, reported separately from the first round
    (which is dominated by XLA trace+compile time; mixing it into the mean
    would swamp the per-method steady-state comparison);
  * measured host syncs per round (every device->host fetch in the FL
    runtime goes through ``core.metrics.host_fetch``; both engines now
    contract to exactly 1 -- the packed stats vector);
  * the fused-over-loop steady-state speedup.

The model is deliberately tiny: the engines run *identical* math, so at
equal compute the ratio isolates per-client dispatch overhead, which is
what dominates FL simulation at the 100+ client scale of the paper's
comparisons.

Emits ``BENCH_round_engine.json`` (committed at the repo root so the perf
trajectory is tracked PR-over-PR).

Usage:  PYTHONPATH=src python benchmarks/round_engine.py \
            [--out PATH] [--clients C ...] [--methods M ...]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

import jax
import numpy as np

from repro.core import metrics
from repro.fl import FLConfig, run_fl
from repro.models.config import ArchConfig

#: every method is benchmarked at this client count (the acceptance bar:
#: >= 2x fused-over-loop for the baselines at 50 clients on CPU) ...
METHOD_CLIENTS = 50
#: ... and GradESTC additionally sweeps the scaling curve.
GRADESTC_CLIENTS = (10, 50, 100)
METHODS = ("gradestc", "topk", "fedpaq", "signsgd", "fedqclip", "svdfed")
WARMUP_ROUNDS = 4          # covers init round + Formula-13 d re-bucketing compiles
MEASURED_ROUNDS = 8


def bench_arch() -> ArchConfig:
    """Dispatch-bound regime: real transformer, minimal per-client compute."""
    return ArchConfig(
        name="fl-bench", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=128, vocab=64, dtype="float32", remat=False,
        attn_chunk=0,
    )


def bench_cfg(method: str, engine: str, n_clients: int) -> FLConfig:
    return FLConfig(
        method=method, rounds=WARMUP_ROUNDS + MEASURED_ROUNDS,
        n_clients=n_clients, local_steps=1, batch=1, seq=8,
        eval_every=10 ** 9, seed=0, arch=bench_arch(), engine=engine,
    )


def measure(method: str, engine: str, n_clients: int) -> dict:
    cfg = bench_cfg(method, engine, n_clients)
    metrics.reset_host_sync_count()
    res = run_fl(cfg)
    syncs = metrics.host_sync_count()
    wall = res.extra["round_wall_s"]
    steady = float(np.median(wall[WARMUP_ROUNDS:]))
    return {
        "engine": res.extra["engine"],
        "method": method,
        "n_clients": n_clients,
        # steady state and trace/compile cost reported separately: round 0
        # is dominated by compilation and would otherwise skew any mean.
        "steady_round_ms": steady * 1e3,
        "first_round_ms": wall[0] * 1e3,
        "rounds_per_sec": 1.0 / steady,
        "host_syncs_per_round": syncs / cfg.rounds,
        "warmup_rounds": WARMUP_ROUNDS,
        "measured_rounds": MEASURED_ROUNDS,
        "total_wall_s": res.wall_s,
        "final_eval_loss": res.eval_loss[-1],
        "uplink_total_bytes": res.ledger.uplink_total,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=str(pathlib.Path(__file__).resolve()
                                         .parent.parent / "BENCH_round_engine.json"))
    ap.add_argument("--clients", type=int, nargs="*", default=None,
                    help="override client counts (applied to every method)")
    ap.add_argument("--methods", nargs="*", default=list(METHODS))
    args = ap.parse_args(argv)

    grid = []
    for method in args.methods:
        counts = (args.clients if args.clients
                  else GRADESTC_CLIENTS if method == "gradestc"
                  else (METHOD_CLIENTS,))
        grid += [(method, C) for C in counts]

    results = []
    speedups: dict = {}
    for method, C in grid:
        loop = measure(method, "loop", C)
        fused = measure(method, "fused", C)
        results += [loop, fused]
        sp = loop["steady_round_ms"] / fused["steady_round_ms"]
        speedups.setdefault(method, {})[str(C)] = sp
        print(f"{method:10s} n_clients={C:4d}  "
              f"loop {loop['steady_round_ms']:8.1f} ms/round "
              f"({loop['host_syncs_per_round']:.1f} syncs)   "
              f"fused {fused['steady_round_ms']:8.1f} ms/round "
              f"({fused['host_syncs_per_round']:.1f} syncs)   "
              f"speedup {sp:.2f}x   "
              f"[first round: loop {loop['first_round_ms']:.0f} ms, "
              f"fused {fused['first_round_ms']:.0f} ms]")

    payload = {
        "benchmark": "round_engine",
        "backend": jax.default_backend(),
        "device": str(jax.devices()[0]),
        "arch": dataclasses.asdict(bench_arch()),
        "config": {"local_steps": 1, "batch": 1, "seq": 8,
                   "methods": args.methods},
        "results": results,
        "speedup_fused_over_loop": speedups,
    }
    pathlib.Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
