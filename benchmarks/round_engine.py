"""Round-engine benchmark: K-round scan-fused chunks vs per-round fused vs
per-client loop, per *method* (the codec protocol runs every Table III
method fused), plus the device-count sweep for the sharded round
(DESIGN.md Secs. 10-11).

Measures, for each method at the configured client counts on the current
backend:

  * steady-state rounds/sec per engine configuration -- the median
    per-round wall time after the warmup span (first chunk of every
    distinct shape), reported separately from the first round;
  * ``first_round_ms`` split into **compile vs execute**: a
    ``jax.monitoring`` listener (``repro.launch.compile_cache.
    CompileWatcher``) attributes compilation-pipeline time received during
    the first chunk's dispatch window.  For K>1 rows the K-length chunk
    executable compiles at *its* first dispatch (chunk 1), so the whole
    cold start is ``compile_ms`` -- with zero mid-run recompiles
    (asserted below) every compile in the run is cold-start cost, and the
    persistent compilation cache -- enabled for every run here -- erases
    most of it on repeat invocations;
  * measured host syncs (every device->host fetch in the FL runtime goes
    through ``core.metrics.host_fetch``): the scan engine's contract is
    **one packed-stats fetch per chunk of K rounds**, so
    ``host_syncs_per_round`` drops to 1/K; eval fetches are counted
    separately via ``FLResult.eval_rounds``;
  * ``mid_run_recompiles`` -- chunk executables compiled beyond one per
    distinct chunk shape.  The rank-padded traced-``d`` codecs make this
    identically 0 (nothing shape-relevant changes between rounds); CI
    asserts it.

The **device sweep** additionally runs the fused engine sharded over
forced host-platform devices (each count in its own subprocess, forcing
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax imports)
at K=1 and K=SCAN_K, reporting per-count round wall, scaling efficiency,
and the scan amortization (K-chunk speedup over per-round dispatch) --
``host_cores`` is recorded alongside: on machines with fewer physical
cores than devices the sweep measures oversubscribed lockstep, not real
scaling.

The model is deliberately tiny: the engines run *identical* math, so at
equal compute the ratio isolates per-round dispatch + host-sync overhead,
which is what dominates FL simulation at the 100+ client scale of the
paper's comparisons.

Emits ``BENCH_round_engine.json`` (committed at the repo root so the perf
trajectory is tracked PR-over-PR).

Usage:  PYTHONPATH=src python benchmarks/round_engine.py \
            [--out PATH] [--clients C ...] [--methods M ...] \
            [--device-sweep N ...] [--smoke]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import tempfile

from repro.launch.env import configure_host

configure_host()  # must precede the first jax import (XLA_FLAGS freeze)

import jax
import numpy as np

from repro.core import metrics
from repro.fl import FLConfig, run_fl
from repro.launch.compile_cache import CompileWatcher, enable_compilation_cache
from repro.models.config import ArchConfig

#: every method is benchmarked at this client count ...
METHOD_CLIENTS = 50
#: ... and GradESTC additionally sweeps the scaling curve.
GRADESTC_CLIENTS = (10, 50, 100)
METHODS = ("gradestc", "topk", "fedpaq", "signsgd", "fedqclip", "svdfed")
#: chunk length for the scan-fused engine rows (K=1 is the per-round
#: fused baseline the acceptance bar compares against).
SCAN_K = 8
#: the sharded-round device sweep (fused engine only).  1/4/8 are the
#: acceptance points; 2 is included because this matters on small hosts:
#: scaling saturates at the physical core count (``host_cores`` rides in
#: the payload), and on a 2-core container the 2-device point is the only
#: one measuring real parallelism rather than oversubscribed lockstep.
DEVICE_SWEEP = (1, 2, 4, 8)
SWEEP_METHODS = ("gradestc", "fedpaq")
WARMUP_ROUNDS = 4          # per-round engines: covers the compile rounds
MEASURED_ROUNDS = 8


def bench_arch() -> ArchConfig:
    """Dispatch-bound regime: real transformer, minimal per-client compute."""
    return ArchConfig(
        name="fl-bench", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=128, vocab=64, dtype="float32", remat=False,
        attn_chunk=0,
    )


def bench_cfg(method: str, engine: str, n_clients: int, *, devices: int = 1,
              scan_rounds: int = 1, rounds: int) -> FLConfig:
    return FLConfig(
        method=method, rounds=rounds,
        n_clients=n_clients, local_steps=1, batch=1, seq=8,
        eval_every=10 ** 9, seed=0, arch=bench_arch(), engine=engine,
        devices=devices, scan_rounds=scan_rounds,
    )


def measure(method: str, engine: str, n_clients: int, *, devices: int = 1,
            scan_rounds: int = 1, rounds: int | None = None,
            agg_block: int = 1) -> dict:
    # warm until the first chunk of every distinct shape has run: chunk 0
    # (length 1, ends at the round-0 eval point) plus one full K chunk.
    warm = (1 + scan_rounds if engine == "fused" and scan_rounds > 1
            else WARMUP_ROUNDS)
    # A K-round chunk yields ONE wall sample per K rounds (its mean), so
    # the scan engine needs K times the rounds for its steady-state median
    # to cover MEASURED_ROUNDS chunk samples -- with a single post-warmup
    # chunk the "median" is one noisy draw.  The K=1 fused row it is
    # compared against must use the *same estimator* (median over means of
    # ``agg_block`` consecutive rounds): a median over single-round walls
    # rejects one-sided OS-jitter spikes that the chunk means of the K>1
    # row necessarily absorb, biasing the scan-amortization ratio low.
    block = (scan_rounds if engine == "fused" and scan_rounds > 1
             else max(1, agg_block) if engine == "fused" else 1)
    # Fused rounds are milliseconds, so double the sample count there; the
    # loop engine is seconds/round and its ratios are far from 1.0 anyway.
    measured = (MEASURED_ROUNDS * block * 2 if engine == "fused"
                else MEASURED_ROUNDS)
    total = warm + measured if rounds is None else rounds
    warm = min(warm, total - 1)
    cfg = bench_cfg(method, engine, n_clients, devices=devices,
                    scan_rounds=scan_rounds, rounds=total)
    watcher = CompileWatcher.install()
    mark = watcher.snapshot()
    metrics.reset_host_sync_count()
    res = run_fl(cfg)
    syncs = metrics.host_sync_count()
    compile_count, compile_s = watcher.since(mark)
    wall = res.extra["round_wall_s"]
    tail = np.asarray(wall[warm:])
    if block > 1 and tail.size >= block:
        tail = tail[:(tail.size // block) * block].reshape(-1, block).mean(1)
    steady = float(np.median(tail))
    spans = res.extra.get("chunk_spans") or []
    first_ms = wall[0] * 1e3
    if spans:      # compile time received during the first chunk's dispatch
        # window: [dispatch start, dispatch end] of chunk 0, so the split
        # decomposes first_round_ms itself (setup compiles before chunk 0
        # -- e.g. the selection-table vmap -- land only in compile_ms).
        # Nested jits traced inline emit their own trace events inside the
        # outer program's, so the summed pipeline time can exceed the wall
        # window; clamp to it (the remainder is the execute share).
        _, first_compile_s = watcher.since(mark, t_start=spans[0][0],
                                           t_end=spans[0][1])
        first_compile_s = min(first_compile_s, first_ms / 1e3)
    else:          # loop engine: compiles spread over the first rounds
        first_compile_s = 0.0
    row = {
        "engine": res.extra["engine"],
        "method": method,
        "n_clients": n_clients,
        "devices": devices,
        "scan_rounds": res.extra.get("scan_rounds", 0),
        # steady state and trace/compile cost reported separately: the
        # first chunk of each shape is dominated by compilation and would
        # otherwise skew any mean.
        "steady_round_ms": steady * 1e3,
        "first_round_ms": first_ms,
        "first_round_compile_ms": first_compile_s * 1e3,
        "first_round_execute_ms": max(0.0, first_ms - first_compile_s * 1e3),
        "compile_ms": compile_s * 1e3,
        "compile_count": compile_count,
        "rounds_per_sec": 1.0 / steady,
        # round accounting syncs only; eval rounds fetch once each and are
        # excluded.  The scan engine fetches once per chunk -> 1/K per
        # round; the loop and K=1 engines stay at exactly 1.
        "host_syncs_per_round": (syncs - len(res.eval_rounds)) / total,
        "chunks": res.extra.get("chunks"),
        "chunk_compiles": res.extra.get("chunk_compiles"),
        "mid_run_recompiles": (
            res.extra["chunk_compiles"] - res.extra["chunk_shapes"]
            if res.extra.get("chunk_compiles", -1) >= 0 else None),
        "warmup_rounds": warm,
        "measured_rounds": total - warm,
        "total_wall_s": res.wall_s,
        "final_eval_loss": res.eval_loss[-1],
        "uplink_total_bytes": res.ledger.uplink_total,
    }
    return row


# ---------------------------------------------------------------------------
# device sweep: one subprocess per device count (XLA fixes the host device
# count at first jax import, so each count needs a fresh process)
# ---------------------------------------------------------------------------

def run_child(devices: int, methods, clients: int, rounds: int | None,
              scan: int, out: pathlib.Path) -> dict:
    env = dict(os.environ)
    configure_host(host_device_count=devices, env=env)
    cmd = [sys.executable, str(pathlib.Path(__file__).resolve()), "--child",
           "--devices", str(devices), "--clients", str(clients),
           "--scan", str(scan), "--methods", *methods, "--out", str(out)]
    if rounds is not None:
        cmd += ["--rounds", str(rounds)]
    subprocess.run(cmd, check=True, env=env)
    return json.loads(out.read_text())


def child_main(args) -> int:
    enable_compilation_cache()
    clients = args.clients[0] if args.clients else METHOD_CLIENTS
    results = []
    for method in args.methods:
        for scan_rounds in (1, args.scan):
            results.append(measure(method, "fused", clients,
                                   devices=args.devices,
                                   scan_rounds=scan_rounds,
                                   rounds=args.rounds,
                                   agg_block=args.scan))
    pathlib.Path(args.out).write_text(json.dumps(results))
    return 0


def device_sweep(sweep, methods, clients: int, rounds: int | None,
                 scan: int) -> dict:
    if jax.default_backend() != "cpu":
        print("device sweep: skipping (forced host devices are CPU-only)")
        return {}
    rows = []
    for n in sweep:
        with tempfile.NamedTemporaryFile(suffix=".json") as tmp:
            rows += run_child(n, methods, clients, rounds, scan,
                              pathlib.Path(tmp.name))
        for r in rows[-2 * len(methods):]:
            print(f"  sweep {r['method']:10s} devices={n} "
                  f"[K={r['scan_rounds']}] "
                  f"{r['steady_round_ms']:7.1f} ms/round "
                  f"({r['host_syncs_per_round']:.2f} syncs/round, "
                  f"{r['mid_run_recompiles']} recompiles)")
    base = {(r["method"]): r["steady_round_ms"] for r in rows
            if r["devices"] == sweep[0] and r["scan_rounds"] == scan}
    speedup, efficiency, amortization = {}, {}, {}
    for r in rows:
        m, n = r["method"], r["devices"]
        if r["scan_rounds"] == scan:
            sp = base[m] / r["steady_round_ms"]
            speedup.setdefault(m, {})[str(n)] = sp
            efficiency.setdefault(m, {})[str(n)] = sp / (n / sweep[0])
        else:     # the K=1 row at the same device count
            kr = next(x for x in rows if x["method"] == m
                      and x["devices"] == n and x["scan_rounds"] == scan)
            amortization.setdefault(m, {})[str(n)] = (
                r["steady_round_ms"] / kr["steady_round_ms"])
    return {
        "clients": clients,
        "methods": list(methods),
        "device_counts": list(sweep),
        "scan_rounds": scan,
        "host_cores": os.cpu_count(),
        "results": rows,
        "speedup_vs_first": speedup,
        "scaling_efficiency": efficiency,
        # >1 means the K-round scan chunk beats per-round dispatch (K=1)
        # at that device count.
        "scan_amortization": amortization,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=str(pathlib.Path(__file__).resolve()
                                         .parent.parent / "BENCH_round_engine.json"))
    ap.add_argument("--clients", type=int, nargs="*", default=None,
                    help="override client counts (applied to every method)")
    ap.add_argument("--methods", nargs="*", default=list(METHODS))
    ap.add_argument("--device-sweep", type=int, nargs="*",
                    default=list(DEVICE_SWEEP),
                    help="device counts for the sharded sweep ([] disables)")
    ap.add_argument("--scan", type=int, default=SCAN_K,
                    help="chunk length K for the scan-fused rows")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 1 method, few rounds, devices 1+2, "
                    "no loop-engine grid")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--devices", type=int, default=1, help=argparse.SUPPRESS)
    ap.add_argument("--rounds", type=int, default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.child:
        return child_main(args)

    enable_compilation_cache()
    sweep_rounds = None
    sweep = args.device_sweep
    # the sweep honors --methods: sweep only the requested subset of the
    # sweep-able methods, and skip it entirely if none was requested
    sweep_methods = [m for m in args.methods if m in SWEEP_METHODS]
    if not sweep_methods:
        sweep = []
    sweep_clients = (args.clients[0] if args.clients else METHOD_CLIENTS)
    if args.scan < 2:
        ap.error("--scan must be >= 2 (K=1 is always benchmarked as the "
                 "per-round fused baseline)")
    scan = args.scan
    if args.smoke:
        args.methods = ["gradestc"]
        sweep_methods = ["gradestc"]
        sweep = [1, 2]
        scan = 4
        sweep_rounds = 1 + scan + 4     # chunk 0 + one K chunk + remainder
        sweep_clients = 8

    results = []
    speedups: dict = {}
    scan_speedups: dict = {}
    if not args.smoke:
        grid = []
        for method in args.methods:
            counts = (args.clients if args.clients
                      else GRADESTC_CLIENTS if method == "gradestc"
                      else (METHOD_CLIENTS,))
            grid += [(method, C) for C in counts]
        for method, C in grid:
            loop = measure(method, "loop", C)
            fused = measure(method, "fused", C, scan_rounds=1,
                            agg_block=scan)
            chunk = measure(method, "fused", C, scan_rounds=scan)
            results += [loop, fused, chunk]
            sp = loop["steady_round_ms"] / fused["steady_round_ms"]
            sc = fused["steady_round_ms"] / chunk["steady_round_ms"]
            speedups.setdefault(method, {})[str(C)] = sp
            scan_speedups.setdefault(method, {})[str(C)] = sc
            print(f"{method:10s} n_clients={C:4d}  "
                  f"loop {loop['steady_round_ms']:8.1f} ms/round   "
                  f"fused(K=1) {fused['steady_round_ms']:7.1f} ms   "
                  f"scan(K={scan}) {chunk['steady_round_ms']:7.1f} ms "
                  f"({chunk['host_syncs_per_round']:.2f} syncs/round)   "
                  f"fused/loop {sp:.2f}x  scan/fused {sc:.2f}x   "
                  f"[first round: {chunk['first_round_compile_ms']:.0f} ms "
                  f"compile + {chunk['first_round_execute_ms']:.0f} ms exec; "
                  f"run compile total {chunk['compile_ms']:.0f} ms]")

    sweep_payload = (device_sweep(sweep, sweep_methods, sweep_clients,
                                  sweep_rounds, scan) if sweep else {})

    payload = {
        "benchmark": "round_engine",
        "backend": jax.default_backend(),
        "device": str(jax.devices()[0]),
        "arch": dataclasses.asdict(bench_arch()),
        "config": {"local_steps": 1, "batch": 1, "seq": 8,
                   "methods": args.methods, "scan_rounds": scan},
        "results": results,
        "speedup_fused_over_loop": speedups,
        "speedup_scan_over_fused": scan_speedups,
        "device_sweep": sweep_payload,
    }
    pathlib.Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
