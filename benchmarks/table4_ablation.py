"""Paper Table IV: ablation of GradESTC components.

Variants: -first (no basis updates), -all (full re-init every round),
-k (incremental but fixed d = k), full (dynamic d), +ef (beyond-paper error
feedback).  "sum_d" is the computational-overhead proxy the paper reports.
"""

from __future__ import annotations

from typing import Dict, List

from repro.fl import FLConfig, run_fl

VARIANTS = ["gradestc-first", "gradestc-all", "gradestc-k", "gradestc",
            "gradestc-ef"]


def run(rounds: int = 15, n_clients: int = 6, seed: int = 0) -> List[Dict]:
    rows = []
    base = None
    for variant in VARIANTS:
        cfg = FLConfig(
            method=variant, rounds=rounds, n_clients=n_clients,
            local_steps=2, batch=8, seq=48, seed=seed,
            eval_every=max(1, rounds // 6),
        )
        res = run_fl(cfg)
        if variant == "gradestc":
            base = res
        rows.append({
            "table": "table4",
            "variant": variant,
            "best_loss": round(min(res.eval_loss), 4),
            "best_acc": round(max(res.eval_acc), 4),
            "total_uplink_mb": round(res.ledger.uplink_total / 2**20, 3),
            "sum_d": res.extra.get("sum_d", ""),
            "wall_s": round(res.wall_s, 1),
        })
    return rows


HEADER = ["table", "variant", "best_loss", "best_acc", "total_uplink_mb",
          "sum_d", "wall_s"]
