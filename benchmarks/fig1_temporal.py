"""Paper Figure 1: temporal correlation of a client's gradients.

Trains one FL client and records per-parameter-group cosine similarity
between the gradient at round r and at earlier rounds -- the empirical
observation motivating GradESTC (strong temporal correlation, concentrated
in the parameter-dominant groups).

Emits rows (group, round_a, round_b, cosine, params) -- the heatmap data of
Fig. 1 plus the Fig. 2 parameter sizes.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import client_batch_stream, make_task
from repro.fl.simulation import default_tiny_arch, _flatten_groups
from repro.models import loss_fn, model, param_group_shapes


def run(rounds: int = 12, seed: int = 0) -> List[Dict]:
    arch = default_tiny_arch()
    task = make_task(vocab=arch.vocab, n_clients=2, seed=seed)
    params = model.init_params(arch, jax.random.PRNGKey(seed))
    stream = client_batch_stream(task, 0, 16, 48, seed)
    groups = list(param_group_shapes(arch).keys())

    grad_fn = jax.jit(lambda p, b: jax.grad(lambda pp: loss_fn(arch, pp, b))(p))

    history: Dict[str, List[np.ndarray]] = {g: [] for g in groups}
    local_steps = 6
    for rnd in range(rounds):
        # one FL round = several local batches; the *round-aggregate*
        # gradient is what clients compress (single-batch gradients are
        # dominated by sampling noise and would under-state the correlation)
        g_acc = None
        for _ in range(local_steps):
            g = grad_fn(params, next(stream))
            g_acc = g if g_acc is None else jax.tree.map(
                lambda a, b: a + b, g_acc, g)
            params = jax.tree.map(
                lambda p, gg: p - 0.05 * gg.astype(p.dtype), params, g)
        flat = _flatten_groups(g_acc, groups)
        for name in groups:
            history[name].append(np.asarray(flat[name], np.float32).ravel())

    rows = []
    sizes = {g: int(np.prod(s)) * st for g, (s, st) in param_group_shapes(arch).items()}
    for name in groups:
        H = history[name]
        for a in range(rounds):
            for b in range(a, rounds):
                va, vb = H[a], H[b]
                cos = float(va @ vb / (np.linalg.norm(va) * np.linalg.norm(vb) + 1e-12))
                rows.append({
                    "table": "fig1",
                    "group": name,
                    "round_a": a,
                    "round_b": b,
                    "cosine": round(cos, 4),
                    "params": sizes[name],
                })
    return rows


def adjacent_summary(rows: List[Dict]) -> List[Dict]:
    """Mean adjacent-round cosine per group (the paper's key statistic)."""
    from collections import defaultdict
    acc = defaultdict(list)
    for r in rows:
        if r["round_b"] == r["round_a"] + 1:
            acc[(r["group"], r["params"])].append(r["cosine"])
    return [
        {
            "table": "fig1_adjacent",
            "group": g,
            "params": p,
            "mean_adjacent_cosine": round(float(np.mean(v)), 4),
        }
        for (g, p), v in sorted(acc.items(), key=lambda kv: -kv[0][1])
    ]


HEADER = ["table", "group", "round_a", "round_b", "cosine", "params"]
HEADER_ADJ = ["table", "group", "params", "mean_adjacent_cosine"]
