"""Minimal but real checkpointing: pytree <-> flat-key .npz.

* keys encode the tree path ("layers/attn_wq", "opt/slots/0/...");
* atomic write (tmp file + rename) so an interrupted save never corrupts the
  latest checkpoint;
* restore takes a *template* pytree (for structure + dtypes) so jit-produced
  sharded arrays round-trip as host numpy and are re-committed by the caller.
"""

from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Dict

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step"]

_SEP = "|"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # .npz cannot serialize ml_dtypes; widen to f32 (the restore
            # template narrows back)
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, step: int, tree: Any) -> str:
    """Write ``<path>/ckpt_<step>.npz`` atomically; returns the file path."""
    os.makedirs(path, exist_ok=True)
    target = os.path.join(path, f"ckpt_{step:08d}.npz")
    # suffix must be .npz: np.savez silently appends it otherwise, and the
    # atomic rename would move an empty file.
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, **_flatten(tree))
        os.replace(tmp, target)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return target


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(path)
        if (m := re.fullmatch(r"ckpt_(\d+)\.npz", f))
    ]
    return max(steps) if steps else None


def restore(path: str, step: int, template: Any) -> Any:
    """Load ``ckpt_<step>.npz`` into the structure of ``template``."""
    data = np.load(os.path.join(path, f"ckpt_{step:08d}.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
