"""Gradient preprocessing: WHDC flattening and (l, m) segmentation.

The paper (Sec. III-A.a) reshapes each gradient tensor into a matrix
``G in R^{l x m}`` whose columns are consecutive length-``l`` segments of the
WHDC-flattened gradient vector ``g in R^n``.  ``l`` is chosen to align with
natural structural boundaries (conv kernels / feature channels / matrix rows)
so that low-rank structure along columns reflects true spatial correlation.

For the transformer-family architectures assigned to this reproduction the
natural boundary of a weight matrix ``W in R^{d_in x d_out}`` is a row (one
input-feature fan-out), so the default segmentation picks ``l`` as the factor
of ``n`` closest to ``sqrt(n)`` that is also a multiple of the row length when
possible -- mirroring the paper's "approximately sqrt(n), aligned with
structure" rule.

All functions are pure and jit-safe (shapes resolved at trace time).
"""

from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "whdc_flatten",
    "whdc_unflatten",
    "segment",
    "unsegment",
    "choose_segment_length",
    "reshape_to_matrix",
    "matrix_to_tensor",
    "pad_to_block",
]


def pad_to_block(x: jnp.ndarray, multiple: int, axis: int = -1) -> Tuple[jnp.ndarray, int]:
    """Zero-pad ``x`` along ``axis`` up to the next multiple of ``multiple``.

    Returns ``(padded, original_size)``.  Shapes are resolved at trace time so
    the pad amount is static; a no-op when already aligned.  Used to feed
    arbitrary (l, m) gradient matrices to the 128-aligned Pallas kernels
    (zero columns project to zero coefficients, so slicing the outputs back
    to ``original_size`` is exact).
    """
    axis = axis % x.ndim
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def whdc_flatten(t: jnp.ndarray) -> jnp.ndarray:
    """Flatten a gradient tensor with WHDC ordering (Fig. 3 of the paper).

    PyTorch conv weights are stored (C_out, C_in, H, W); WHDC ordering walks
    Width fastest, then Height, Depth (C_in), Channel (C_out).  For a tensor
    stored row-major in (C, D, H, W) order that is exactly a plain ravel.  For
    2-D matrices (the transformer case) it degenerates to row-major ravel.
    """
    return t.reshape(-1)


def whdc_unflatten(g: jnp.ndarray, shape: Sequence[int]) -> jnp.ndarray:
    """Inverse of :func:`whdc_flatten`."""
    return g.reshape(tuple(shape))


def choose_segment_length(shape: Sequence[int], l_hint: int | None = None) -> int:
    """Pick the column length ``l`` for a gradient of the given tensor shape.

    Follows the paper's rule: "l is set to approximately the square root of
    n, aligning with natural structural boundaries".  Preference order:

    1. an explicit ``l_hint`` (must divide n),
    2. a multiple of the trailing-dimension length closest to sqrt(n),
    3. the divisor of n closest to sqrt(n).
    """
    n = int(np.prod(shape))
    if l_hint is not None:
        if n % l_hint != 0:
            raise ValueError(f"l_hint={l_hint} does not divide n={n}")
        return l_hint

    root = math.isqrt(n)
    trailing = int(shape[-1])
    # Candidate 1: multiples of the trailing dim nearest sqrt(n).
    if trailing <= n:
        k = max(1, round(root / trailing))
        for cand in (k * trailing, (k + 1) * trailing, max(1, k - 1) * trailing):
            if cand > 0 and n % cand == 0:
                return cand
    # Candidate 2: nearest divisor of n to sqrt(n).
    best = 1
    for d in range(1, root + 1):
        if n % d == 0:
            best = d
    other = n // best
    return best if abs(best - root) <= abs(other - root) else other


def segment(g: jnp.ndarray, l: int) -> jnp.ndarray:
    """Reshape flat vector ``g in R^n`` to ``G in R^{l x m}``, column-major
    segments: ``G[:, j] = g[j*l : (j+1)*l]`` (paper Sec. III-A.a)."""
    n = g.shape[-1]
    if n % l != 0:
        raise ValueError(f"segment length l={l} must divide n={n}")
    m = n // l
    # g -> (m, l) row blocks, transpose so each column is a consecutive segment.
    return g.reshape(*g.shape[:-1], m, l).swapaxes(-1, -2)


def unsegment(G: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`segment`: ``(..., l, m) -> (..., n)``."""
    l, m = G.shape[-2], G.shape[-1]
    return G.swapaxes(-1, -2).reshape(*G.shape[:-2], l * m)


def reshape_to_matrix(t: jnp.ndarray, l: int | None = None) -> Tuple[jnp.ndarray, Tuple[int, ...], int]:
    """Full preprocessing: tensor -> (G, original_shape, l)."""
    shape = tuple(int(s) for s in t.shape)
    l_val = choose_segment_length(shape, l)
    G = segment(whdc_flatten(t), l_val)
    return G, shape, l_val


def matrix_to_tensor(G: jnp.ndarray, shape: Sequence[int]) -> jnp.ndarray:
    """Inverse of :func:`reshape_to_matrix`."""
    return whdc_unflatten(unsegment(G), shape)
