"""Stateless functional codec protocol (DESIGN.md Sec. 9).

Every uplink compression method -- the paper's GradESTC *and* the six
Table III baselines -- is expressed as a :class:`Codec`: a pure-functional,
per-parameter-group compressor whose state is explicit arrays (no Python
dicts keyed by ``(client, path)``).  The contract is what lets one round
engine serve every method:

  * ``init_client_state(n_clients)`` returns the per-client state stacked on
    a leading client axis (``()`` for stateless codecs), so a whole round of
    client encodes is ``vmap(encode)`` over that axis;
  * ``init_shared_state()`` returns server-side state shared by all clients
    (SVDFed's basis; ``()`` for the rest);
  * ``encode(cstate, shared, key, wire)`` is the per-client step: returns
    the new client state, the server-side reconstruction in wire layout,
    and a small **int32 stats vector** -- the only thing the host ever
    needs to see.  It is **branch-free across rounds**: no static ``d``,
    no init/update ``mode`` -- every per-round configuration that used to
    be a jit-static argument is a traced value over rank-padded buffers
    (GradESTC's Formula-13 candidate count ``d`` rides the shared state as
    a traced int32 and masks a ``d_max``-capacity sketch;
    ``core/gradestc.compress_step``), so one compiled program serves every
    round and the whole round chain can live inside a ``lax.scan``;
  * ``reduce_stats`` / ``update_shared`` run in-jit after the client vmap
    (cross-client stat reduction; SVDFed's conditional basis refit;
    GradESTC's in-jit Formula 13 advancing ``d`` for the next round);
  * ``charge_bits`` is a host-side pure function over the fetched stats:
    exact integer bit accounting (Formula 14 and each baseline's wire
    format).  Everything the host needs -- including the ``d`` a round
    actually used -- travels in the packed stats vector.

Layout: a codec owns its wire layout via ``to_wire`` / ``from_wire``.
GradESTC works on stacked ``(L, l, m)`` segment matrices; the per-tensor
baselines use the flat ``(n,)`` group vector (stacked to ``(C, n)`` across
clients by the engine's vmap, the flat analogue of GradESTC's
``(C, L, l, k)`` basis stacking).

Byte accounting is **integer bits** end to end: ``charge_bits`` returns a
Python int, and the ledger accumulates those integer bits directly
(``CommLedger.charge_uplink_bits`` -- no float scalar conversion anywhere,
so f32/f64 rounding above 2^24 scalars cannot skew Table III totals the way
the old per-tensor ``float(sc)`` accumulation could).  Data-dependent
counts (GradESTC's d_r and per-round d, SVDFed's refit flag) travel in the
packed stats vector; everything else is shape-static.

PRNG: every stream is a ``fold_in`` chain (PYTHONHASHSEED-independent, and
derivable from traced ints inside a jitted round): per-round codec
randomness from :func:`round_base_key` + ``Codec.per_client_key``, GradESTC
basis keys from :func:`client_layer_keys`.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import baselines as bl
from . import gradestc as ge
from .policy import LayerPlan
from .rsvd import randomized_svd

__all__ = [
    "Codec", "EFCodec", "TopKCodec", "FedPAQCodec", "SignSGDCodec",
    "FedQClipCodec", "SVDFedCodec", "GradESTCCodec",
    "client_layer_keys", "round_base_key", "SERVER_CLIENT_ID",
]

#: Client id used for server-side (downlink) codec instances -- the masked
#: ``-1`` the reference runtime always used for the shared codec.
SERVER_CLIENT_ID = 0xFFFFFFFF


def client_layer_keys(seed: int, client, path_idx, L: int) -> jnp.ndarray:
    """Per-(client, group) rSVD key stack, one key per stacked layer.

    Derived with ``fold_in`` chains only -- NOT Python ``hash()``, whose
    string hashing is salted by ``PYTHONHASHSEED`` and therefore differs
    across processes.  ``client``/``path_idx`` may be traced int32 scalars,
    so the same derivation runs inside the fused engine's jitted round and
    in the host reference loop, producing identical streams.
    """
    if isinstance(client, int):
        client &= 0xFFFFFFFF    # server-side codecs use client=-1
    base = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), client), path_idx
    )
    return jax.random.split(base, L)


def round_base_key(seed: int, rnd: int) -> jax.Array:
    """Per-round base for codec randomness (quantizer draws).  Folded with
    the client id and group index by ``Codec.per_client_key``, so both
    engines consume identical streams without threading a split chain."""
    return jax.random.fold_in(jax.random.PRNGKey(seed + 0x5EED), rnd)


class Codec:
    """Contract for one parameter group's compressor (see module docstring).

    Subclasses override what they need; the defaults describe a stateless,
    stats-free identity-layout codec.  All array-touching methods must be
    shape-polymorphic pure functions (they run under vmap/jit); all host
    methods take/return plain Python ints.
    """

    #: length of the per-client int32 stats vector returned by ``encode``
    client_stats_len: int = 0
    #: length of the reduced per-group stats vector (packed host transfer)
    stats_len: int = 0

    def __init__(self, path_idx: int = 0):
        self.path_idx = path_idx

    # -- state -------------------------------------------------------------
    def init_client_state(self, n_clients: int, client_ids=None):
        return ()

    def init_shared_state(self):
        return ()

    # -- wire layout -------------------------------------------------------
    def to_wire(self, delta: jnp.ndarray) -> jnp.ndarray:
        """Group-shaped per-client delta -> codec wire layout (f32)."""
        return delta

    def from_wire(self, wire: jnp.ndarray, shape) -> jnp.ndarray:
        return wire.reshape(shape)

    # -- per-client encode (vmapped over the client axis by the engine) ----
    def encode(self, cstate, shared, key, wire):
        """-> (cstate', recon_wire, stats int32 (client_stats_len,)).

        Must be branch-free across rounds: no jit-static per-round
        arguments.  Round-varying configuration rides ``shared`` (traced)
        or ``cstate`` (per-client traced flags)."""
        raise NotImplementedError

    # -- in-jit cross-client reduction / server-side update ----------------
    def reduce_stats(self, stats: jnp.ndarray) -> jnp.ndarray:
        """(C, client_stats_len) -> (stats_len,) int32."""
        return jnp.zeros((0,), jnp.int32)

    def update_shared(self, shared, reduced_stats, mean_wire):
        return shared

    # -- host side ---------------------------------------------------------
    def per_client_key(self, base_key, client):
        """Per-(round, client, group) randomness; ``client`` may be traced."""
        return jax.random.fold_in(jax.random.fold_in(base_key, client),
                                  self.path_idx)

    def charge_bits(self, reduced: np.ndarray, n_sel: int) -> int:
        """Exact uplink bits for ``n_sel`` clients this round (Python int).

        Every data-dependent count it needs must travel in ``reduced`` --
        there is no host-side per-round config left to consult."""
        raise NotImplementedError

    def host_metrics(self, reduced: np.ndarray, n_sel: int) -> Dict[str, int]:
        """Optional per-round host-side metric increments (e.g. sum_d)."""
        return {}


# ---------------------------------------------------------------------------
# per-tensor baselines: flat (n,) wire layout
# ---------------------------------------------------------------------------

class _FlatCodec(Codec):
    """Shared flat-vector layout for the per-tensor baselines."""

    def __init__(self, n: int, path_idx: int = 0):
        super().__init__(path_idx)
        self.n = int(n)

    def to_wire(self, delta: jnp.ndarray) -> jnp.ndarray:
        return delta.reshape(-1).astype(jnp.float32)


class TopKCodec(_FlatCodec):
    """Magnitude top-k with per-client error memory (ref [23]).

    Wire: k values + k int32 indices -> 2k * 32 bits per client.
    """

    def __init__(self, n: int, frac: float = 0.1, path_idx: int = 0):
        super().__init__(n, path_idx)
        self.k = max(1, int(frac * self.n))

    def init_client_state(self, n_clients: int, client_ids=None):
        return jnp.zeros((n_clients, self.n), jnp.float32)

    def encode(self, cstate, shared, key, wire):
        st, ghat, _ = bl.topk_compress(bl.TopKState(cstate), wire, self.k)
        return st.memory, ghat, jnp.zeros((0,), jnp.int32)

    def charge_bits(self, reduced, n_sel):
        return 32 * 2 * self.k * n_sel


class FedPAQCodec(_FlatCodec):
    """Stochastic uniform quantization (ref [21]).

    ``use_pallas=False``: the paper's global-max-abs scale
    (``core.baselines.quantize_stochastic``) -- n*bits + one 32-bit scale.
    ``use_pallas=True``: the TPU-native block-local quantizer
    (``kernels/quant.py`` via the ``kernels.ops`` dispatch) -- n*bits plus
    one 32-bit scale per ``block`` entries.
    """

    def __init__(self, n: int, bits: int = 8, path_idx: int = 0,
                 use_pallas: bool = False,
                 pallas_interpret: Optional[bool] = None, block: int = 512):
        super().__init__(n, path_idx)
        self.bits = int(bits)
        self.use_pallas = bool(use_pallas)
        self.pallas_interpret = pallas_interpret
        self.block = int(block)

    def _quantize(self, g, key):
        from repro.kernels.ops import quantize_update

        return quantize_update(
            g, key, bits=self.bits, block=self.block,
            use_pallas=self.use_pallas, interpret=self.pallas_interpret,
        )

    def encode(self, cstate, shared, key, wire):
        return (), self._quantize(wire, key), jnp.zeros((0,), jnp.int32)

    @property
    def _n_scales(self) -> int:
        return -(-self.n // self.block) if self.use_pallas else 1

    def charge_bits(self, reduced, n_sel):
        return (self.n * self.bits + 32 * self._n_scales) * n_sel


class SignSGDCodec(_FlatCodec):
    """1-bit sign compression with a mean-magnitude scale (ref [20]).

    ``encode`` materializes the packed 1-bit wire (``kernels.ops.sign_wire``:
    32 signs per uint32 word + one mean-|g| scale) and reconstructs from it,
    so the dense bits the ledger charges for actually exist on device.  Wire
    semantics: bit = (g < 0), so an exact zero ships as +scale (a 1-bit code
    book has no zero; ``jnp.sign``'s 0 -> 0 is unrepresentable), and the
    scale uses the canonical two-stage (rows, 512) reduction -- both engines
    share this codec, so engine parity is untouched.  ``use_pallas`` selects
    the fused sign-pack kernel (interpret off-TPU) over the jnp oracle;
    the two are bit-exact.
    """

    def __init__(self, n: int, path_idx: int = 0, use_pallas: bool = False,
                 pallas_interpret: Optional[bool] = None):
        super().__init__(n, path_idx)
        self.use_pallas = bool(use_pallas)
        self.pallas_interpret = pallas_interpret

    def encode(self, cstate, shared, key, wire):
        from repro.kernels import ops

        words, scale = ops.sign_wire(
            wire, use_kernel=self.use_pallas, interpret=self.pallas_interpret)
        ghat = ops.sign_unwire(
            words, scale, self.n,
            use_kernel=self.use_pallas, interpret=self.pallas_interpret)
        return (), ghat, jnp.zeros((0,), jnp.int32)

    def charge_bits(self, reduced, n_sel):
        return (self.n + 32) * n_sel


class FedQClipCodec(FedPAQCodec):
    """Clipped + quantized updates (ref [42]); same wire as FedPAQ."""

    def __init__(self, n: int, clip: float = 100.0, bits: int = 8,
                 path_idx: int = 0, use_pallas: bool = False,
                 pallas_interpret: Optional[bool] = None, block: int = 512):
        super().__init__(n, bits, path_idx, use_pallas, pallas_interpret, block)
        self.clip = float(clip)

    def encode(self, cstate, shared, key, wire):
        norm = jnp.linalg.norm(wire)
        clipped = wire * jnp.minimum(1.0, self.clip / jnp.maximum(norm, 1e-12))
        return (), self._quantize(clipped, key), jnp.zeros((0,), jnp.int32)


# ---------------------------------------------------------------------------
# matrix-layout codecs: stacked (L, l, m) segment matrices
# ---------------------------------------------------------------------------

class _MatrixCodec(Codec):
    """Shared (L, l, m) segment-matrix layout (``columns = segments``)."""

    def __init__(self, plan: LayerPlan, path_idx: int = 0):
        super().__init__(path_idx)
        self.plan = plan

    def to_wire(self, delta: jnp.ndarray) -> jnp.ndarray:
        plan = self.plan
        flat = delta.reshape(plan.stack, -1)
        m = plan.n // plan.l
        return (flat.reshape(plan.stack, m, plan.l)
                .swapaxes(-1, -2).astype(jnp.float32))

    def from_wire(self, wire: jnp.ndarray, shape) -> jnp.ndarray:
        plan = self.plan
        flat = wire.swapaxes(-1, -2).reshape(plan.stack, plan.n)
        return flat.reshape(shape)


#: bits per coefficient entry for each coefficient wire format
_WIRE_DTYPE_BITS = {"f32": 32, "bf16": 16, "int8": 8}


def _coeff_wire_bits(wire_dtype: str, k: int, m: int) -> int:
    """Exact uplink bits for one (k, m) coefficient matrix on the wire.

    Entries ship at the wire dtype's width; the int8 format additionally
    ships one f32 scale per (row, 512-column block) (``ref.WIRE_BLOCK``).
    "f32" reproduces the historical ``32 * k * m`` exactly, so default-config
    ledgers are bit-for-bit unchanged.
    """
    bits = _WIRE_DTYPE_BITS[wire_dtype] * k * m
    if wire_dtype == "int8":
        bits += 32 * k * (-(-m // 512))
    return bits


class SVDFedCodec(_MatrixCodec):
    """Globally shared per-group basis (ref [12]), round-granular refits.

    The shared basis M lives server-side; clients upload coefficients
    ``A = M^T G`` between refits.  A *refit round* ships raw G from every
    client (full uplink, SVDFed's calibration cost) and the server re-fits
    M from the aggregated gradient in-jit.  The refit decision is taken at
    round granularity: if any client's relative fitting error exceeds
    ``gamma``% this round, the *next* round is a refit round.  (The old
    host-dict implementation flipped mid-round in client-iteration order,
    which no client-symmetric vmap can reproduce; round granularity is the
    deterministic formulation both engines share.)  Round 0 is always a
    refit round (M starts empty).
    """

    #: stats: [is_refit_round, wants_refit_next]
    client_stats_len = 2
    stats_len = 2

    def __init__(self, plan: LayerPlan, gamma: float = 8.0, seed: int = 0,
                 path_idx: int = 0, use_pallas: bool = False,
                 pallas_interpret: Optional[bool] = None,
                 wire_dtype: str = "f32"):
        assert wire_dtype in ("f32", "bf16", "int8")
        super().__init__(plan, path_idx)
        self.gamma = float(gamma)
        self.seed = int(seed)
        self.use_pallas = bool(use_pallas)
        self.pallas_interpret = pallas_interpret
        self.wire_dtype = wire_dtype

    def init_shared_state(self):
        plan = self.plan
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 17),
                                 self.path_idx)
        return (jnp.zeros((plan.stack, plan.l, plan.k), jnp.float32),
                key, jnp.ones((), jnp.bool_))

    def encode(self, cstate, shared, key, wire):
        M, _, refit = shared
        if self.wire_dtype == "int8":
            # SVDFed's steady state IS project + quantize, so the int8 wire
            # fuses into one encode_quant kernel pass per layer; the
            # residual E comes back against the *shipped* coefficients.
            from repro.kernels import ops

            codes, scales, E = jax.vmap(functools.partial(
                ops.encode_quant, use_kernel=self.use_pallas,
                interpret=self.pallas_interpret))(M, wire)
            Ghat = jax.vmap(functools.partial(
                ops.decode_wire, use_kernel=self.use_pallas,
                interpret=self.pallas_interpret))(M, codes, scales)
            err = jnp.sum(E.astype(jnp.float32) ** 2)
        else:
            A = jnp.einsum("xlk,xlm->xkm", M, wire)
            if self.wire_dtype == "bf16":
                from repro.kernels import ops

                A = jax.vmap(functools.partial(
                    ops.coeff_roundtrip, wire_dtype="bf16"))(A)
            Ghat = jnp.einsum("xlk,xkm->xlm", M, A)
            err = jnp.sum((wire - Ghat).astype(jnp.float32) ** 2)
        recon = jnp.where(refit, wire, Ghat)
        den = jnp.maximum(jnp.sum(wire.astype(jnp.float32) ** 2), 1e-30)
        thresh = (self.gamma / 100.0) ** 2
        want = jnp.logical_and(~refit, err > thresh * den)
        stats = jnp.stack([refit, want]).astype(jnp.int32)
        return (), recon, stats

    def reduce_stats(self, stats):
        return jnp.max(stats, axis=0).astype(jnp.int32)

    def update_shared(self, shared, reduced_stats, mean_wire):
        M, key, refit = shared
        key2, sub = jax.random.split(key)

        def _fit(_):
            subs = jax.random.split(sub, self.plan.stack)
            return jax.vmap(
                lambda g, kk: randomized_svd(kk, g, rank=self.plan.k)[0]
            )(mean_wire, subs)

        M2 = jax.lax.cond(refit, _fit, lambda _: M, operand=None)
        return (M2, key2, reduced_stats[1] > 0)

    def charge_bits(self, reduced, n_sel):
        plan = self.plan
        if int(reduced[0]):                       # refit round: raw uplink
            return 32 * plan.raw_scalars * n_sel
        bits = _coeff_wire_bits(self.wire_dtype, plan.k, plan.m) * plan.stack
        return bits * n_sel


class GradESTCCodec(_MatrixCodec):
    """The paper's spatio-temporal compressor (Algorithms 1-2), rank-padded.

    Per-client state: basis stack ``(L, l, k)``, rSVD key stack ``(L, 2)``,
    per-layer init flags ``(L,)`` -- stacked to ``(C, ...)`` by the engine.
    The Formula-13 candidate count ``d`` is a **traced** int32 riding the
    *shared* state: ``encode`` masks a static ``d_max``-capacity sketch
    (``core/gradestc.compress_step``) with it, and ``update_shared``
    advances it in-jit from the round's reduced stats
    (:func:`repro.core.gradestc.next_candidate_count_jax` -- the paper's
    exact rule, no power-of-two bucketing).  One compiled program therefore
    serves init, steady-state, and mixed partial-participation rounds: an
    uninitialized layer (``M = 0``, init flag False) takes the same path
    with ``R_old = -inf`` and a full-capacity sketch, which is bit-identical
    to the dedicated init round.

    Stats per client: ``[max d_r over updating layers, n_upd = #updating
    layers, sum d_r, d used this round]`` -- reduced across clients to
    ``[drmax, n_upd, sum_dr, d]``, from which the host rebuilds Formula 14
    in exact integer arithmetic (inits are the ``n_sel*stack - n_upd``
    complement) and the ``sum_d`` compute proxy.
    """

    client_stats_len = 4
    stats_len = 4

    def __init__(self, plan: LayerPlan, seed: int = 0, path_idx: int = 0,
                 variant: str = "full", alpha: float = 1.3, beta: float = 1.0,
                 use_pallas: bool = False,
                 pallas_interpret: Optional[bool] = None,
                 wire_dtype: str = "f32"):
        assert variant in ("full", "first", "all", "k")
        assert wire_dtype in ("f32", "bf16", "int8")
        super().__init__(plan, path_idx)
        self.seed = int(seed)
        self.variant = variant
        self.alpha, self.beta = float(alpha), float(beta)
        self.use_pallas = bool(use_pallas)
        self.pallas_interpret = pallas_interpret
        #: coefficient wire format (basis vectors always ship f32 -- see
        #: core.gradestc.compress_step)
        self.wire_dtype = wire_dtype

    def init_client_state(self, n_clients: int, client_ids=None):
        plan = self.plan
        L, l, k = plan.stack, plan.l, plan.k
        ids = (jnp.arange(n_clients) if client_ids is None
               else jnp.asarray(client_ids, jnp.uint32))
        return (
            jnp.zeros((n_clients, L, l, k), jnp.float32),
            jax.vmap(lambda c: client_layer_keys(self.seed, c, self.path_idx, L))(ids),
            jnp.zeros((n_clients, L), jnp.bool_),
        )

    def init_shared_state(self):
        """The traced per-group Formula-13 candidate count ``d``."""
        k = self.plan.k
        d0 = k if self.variant == "k" else max(1, k // 4)
        return jnp.asarray(d0, jnp.int32)

    def _round_d(self, shared) -> jnp.ndarray:
        """The candidate count updating layers use this round (traced).

        Note the deliberate tradeoff for the ``first`` ablation: its frozen
        basis masks every candidate (d = 0), but the rank-``d_max`` sketch
        still executes -- XLA cannot dead-code it behind a traced mask, and
        skipping it would need a per-round init/steady branch, the exact
        machinery the branch-free contract retired.  Its *uplink* numbers
        (what Table IV compares) and its ``sum_d`` compute proxy are
        unaffected; only ablation wall-clock pays."""
        if self.variant == "first":      # frozen basis: nothing ever enters
            return jnp.zeros((), jnp.int32)
        if self.variant == "k":          # fixed d = k ablation
            return jnp.asarray(self.plan.k, jnp.int32)
        return jnp.asarray(shared, jnp.int32)

    def encode(self, cstate, shared, key, wire):
        plan = self.plan
        M, keys, inited = cstate
        d = self._round_d(shared)
        if self.variant == "all":        # re-initialize every round
            inited = jnp.zeros_like(inited)
        # Decode (Ghat = M A) takes the same use_pallas switch as encode:
        # server-side reconstruction and the downlink decode path both run
        # through the blocked Pallas decode kernel (interpret off-TPU).
        recon = functools.partial(ge.reconstruct, use_pallas=self.use_pallas,
                                  pallas_interpret=self.pallas_interpret)

        def step(M_l, key_l, init_l, G):
            st = ge.CompressorState(M=M_l, key=key_l, initialized=init_l)
            st2, payload, stats = ge.compress_step(
                st, G, k=plan.k, d=d, d_max=plan.d_max,
                use_pallas=self.use_pallas,
                pallas_interpret=self.pallas_interpret,
                wire_dtype=self.wire_dtype,
            )
            return (st2.M, st2.key, recon(st2.M, payload.coeffs),
                    stats.d_r, payload.init)

        M2, K2, Ghat, d_r, was_init = jax.vmap(step)(M, keys, inited, wire)
        # d_r on update branches only; inits (d_r == k) are reported via the
        # n_upd count instead, so the host can reconstruct Formula 14 in
        # exact integer arithmetic.
        upd_dr = jnp.where(was_init, 0, d_r).astype(jnp.int32)
        stats = jnp.stack([
            jnp.max(upd_dr),
            jnp.sum(~was_init).astype(jnp.int32),
            jnp.sum(upd_dr),
            d,
        ])
        return ((M2, K2, jnp.ones((M2.shape[0],), jnp.bool_)), Ghat, stats)

    def reduce_stats(self, stats):
        return jnp.stack([
            jnp.max(stats[:, 0]), jnp.sum(stats[:, 1]), jnp.sum(stats[:, 2]),
            jnp.max(stats[:, 3]),
        ]).astype(jnp.int32)

    def update_shared(self, shared, reduced_stats, mean_wire):
        if self.variant != "full":       # d fixed for the ablations
            return shared
        drmax, n_upd = reduced_stats[0], reduced_stats[1]
        d2 = ge.next_candidate_count_jax(drmax, self.plan.k,
                                         self.alpha, self.beta)
        # init-only rounds (n_upd == 0) carry d forward unchanged, matching
        # the old host rule -- a round with no updating layer has no d_r.
        return jnp.where(n_upd > 0, d2,
                         jnp.asarray(shared, jnp.int32)).astype(jnp.int32)

    def charge_bits(self, reduced, n_sel):
        plan = self.plan
        n_upd, sum_dr = int(reduced[1]), int(reduced[2])
        n_init = n_sel * plan.stack - n_upd
        # Formula 14: inits ship the basis (k*l, always f32) + coefficients;
        # updates ship coefficients + the d_r entering vectors (f32) and
        # their indices.  Coefficients ship at the wire dtype's width
        # (f32 reproduces the historical 32*k*m exactly).
        coeff = _coeff_wire_bits(self.wire_dtype, plan.k, plan.m)
        return (n_init * (32 * plan.k * plan.l + coeff)
                + n_upd * coeff
                + 32 * sum_dr * (plan.l + 1))

    def host_metrics(self, reduced, n_sel):
        # Computational-overhead proxy (Table IV): every init pays a rank-k
        # sketch, every update a rank-d sketch (d only spent for full / k;
        # the round's d travels in the stats -- reduced[3]).
        n_upd = int(reduced[1])
        n_init = n_sel * self.plan.stack - n_upd
        inc = self.plan.k * n_init
        if self.variant in ("full", "k"):
            inc += int(reduced[3]) * n_upd
        return {"sum_d": inc}


class EFCodec(Codec):
    """Error-feedback wrapper (paper Sec. VI / beyond-paper ``-ef``):
    client memory accumulates the compression residual in wire layout and
    re-injects it before the inner encode."""

    def __init__(self, inner: Codec, mem_shape: Tuple[int, ...]):
        super().__init__(inner.path_idx)
        self.inner = inner
        self.mem_shape = tuple(int(s) for s in mem_shape)
        self.client_stats_len = inner.client_stats_len
        self.stats_len = inner.stats_len

    def init_client_state(self, n_clients: int, client_ids=None):
        return (self.inner.init_client_state(n_clients, client_ids),
                jnp.zeros((n_clients,) + self.mem_shape, jnp.float32))

    def init_shared_state(self):
        return self.inner.init_shared_state()

    def to_wire(self, delta):
        return self.inner.to_wire(delta)

    def from_wire(self, wire, shape):
        return self.inner.from_wire(wire, shape)

    def encode(self, cstate, shared, key, wire):
        inner_st, mem = cstate
        injected = wire + mem
        inner_st2, recon, stats = self.inner.encode(
            inner_st, shared, key, injected)
        return (inner_st2, injected - recon), recon, stats

    def reduce_stats(self, stats):
        return self.inner.reduce_stats(stats)

    def update_shared(self, shared, reduced_stats, mean_wire):
        return self.inner.update_shared(shared, reduced_stats, mean_wire)

    def charge_bits(self, reduced, n_sel):
        return self.inner.charge_bits(reduced, n_sel)

    def host_metrics(self, reduced, n_sel):
        return self.inner.host_metrics(reduced, n_sel)
