"""Stateless functional codec protocol (DESIGN.md Sec. 9).

Every uplink compression method -- the paper's GradESTC *and* the six
Table III baselines -- is expressed as a :class:`Codec`: a pure-functional,
per-parameter-group compressor whose state is explicit arrays (no Python
dicts keyed by ``(client, path)``).  The contract is what lets one round
engine serve every method:

  * ``init_client_state(n_clients)`` returns the per-client state stacked on
    a leading client axis (``()`` for stateless codecs), so a whole round of
    client encodes is ``vmap(encode)`` over that axis;
  * ``init_shared_state()`` returns server-side state shared by all clients
    (SVDFed's basis; ``()`` for the rest);
  * ``encode(cstate, shared, key, wire, static, mode)`` is the per-client
    step: returns the new client state, the server-side reconstruction in
    wire layout, and a small **int32 stats vector** -- the only thing the
    host ever needs to see;
  * ``reduce_stats`` / ``update_shared`` run in-jit after the client vmap
    (cross-client stat reduction; SVDFed's conditional basis refit);
  * ``charge_bits`` / ``init_static`` / ``next_static`` are host-side pure
    functions over the fetched stats: exact integer bit accounting
    (Formula 14 and each baseline's wire format) and the per-round static
    configuration (GradESTC's Formula 13 candidate count ``d``).

Layout: a codec owns its wire layout via ``to_wire`` / ``from_wire``.
GradESTC works on stacked ``(L, l, m)`` segment matrices; the per-tensor
baselines use the flat ``(n,)`` group vector (stacked to ``(C, n)`` across
clients by the engine's vmap, the flat analogue of GradESTC's
``(C, L, l, k)`` basis stacking).

Byte accounting is **integer bits** end to end: ``charge_bits`` returns a
Python int, and the ledger is charged ``bits / 32`` scalars (exact -- a
dyadic rational, so f32/f64 rounding above 2^24 scalars cannot skew
Table III totals the way the old per-tensor ``float(sc)`` accumulation
could).  Data-dependent counts (GradESTC's d_r, SVDFed's refit flag) travel
in the packed stats vector; everything else is shape-static.

PRNG: every stream is a ``fold_in`` chain (PYTHONHASHSEED-independent, and
derivable from traced ints inside a jitted round): per-round codec
randomness from :func:`round_base_key` + ``Codec.per_client_key``, GradESTC
basis keys from :func:`client_layer_keys`.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import baselines as bl
from . import gradestc as ge
from .policy import LayerPlan
from .rsvd import randomized_svd

__all__ = [
    "Codec", "EFCodec", "TopKCodec", "FedPAQCodec", "SignSGDCodec",
    "FedQClipCodec", "SVDFedCodec", "GradESTCCodec",
    "client_layer_keys", "round_base_key", "SERVER_CLIENT_ID",
]

#: Client id used for server-side (downlink) codec instances -- the masked
#: ``-1`` the reference runtime always used for the shared codec.
SERVER_CLIENT_ID = 0xFFFFFFFF


def client_layer_keys(seed: int, client, path_idx, L: int) -> jnp.ndarray:
    """Per-(client, group) rSVD key stack, one key per stacked layer.

    Derived with ``fold_in`` chains only -- NOT Python ``hash()``, whose
    string hashing is salted by ``PYTHONHASHSEED`` and therefore differs
    across processes.  ``client``/``path_idx`` may be traced int32 scalars,
    so the same derivation runs inside the fused engine's jitted round and
    in the host reference loop, producing identical streams.
    """
    if isinstance(client, int):
        client &= 0xFFFFFFFF    # server-side codecs use client=-1
    base = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), client), path_idx
    )
    return jax.random.split(base, L)


def round_base_key(seed: int, rnd: int) -> jax.Array:
    """Per-round base for codec randomness (quantizer draws).  Folded with
    the client id and group index by ``Codec.per_client_key``, so both
    engines consume identical streams without threading a split chain."""
    return jax.random.fold_in(jax.random.PRNGKey(seed + 0x5EED), rnd)


class Codec:
    """Contract for one parameter group's compressor (see module docstring).

    Subclasses override what they need; the defaults describe a stateless,
    stats-free identity-layout codec.  All array-touching methods must be
    shape-polymorphic pure functions (they run under vmap/jit); all host
    methods take/return plain Python ints.
    """

    #: length of the per-client int32 stats vector returned by ``encode``
    client_stats_len: int = 0
    #: length of the reduced per-group stats vector (packed host transfer)
    stats_len: int = 0
    #: True when the first selection of a client compiles a different branch
    #: (the engine tracks host-side which clients are initialized and
    #: specializes the round's ``mode`` to keep steady rounds cond-free)
    has_init_branch: bool = False
    #: True when ``next_static`` can actually move the static config between
    #: rounds (GradESTC's Formula 13 d re-bucketing).  The pipelined engine
    #: speculates across the deferred stats fetch only for dynamic-static
    #: codecs; static-free codecs always speculate for free -- and the
    #: engine keeps the round's inputs un-donated exactly when a
    #: speculation miss could force a redispatch.
    dynamic_static: bool = False

    def __init__(self, path_idx: int = 0):
        self.path_idx = path_idx

    # -- state -------------------------------------------------------------
    def init_client_state(self, n_clients: int, client_ids=None):
        return ()

    def init_shared_state(self):
        return ()

    # -- wire layout -------------------------------------------------------
    def to_wire(self, delta: jnp.ndarray) -> jnp.ndarray:
        """Group-shaped per-client delta -> codec wire layout (f32)."""
        return delta

    def from_wire(self, wire: jnp.ndarray, shape) -> jnp.ndarray:
        return wire.reshape(shape)

    # -- per-client encode (vmapped over the client axis by the engine) ----
    def encode(self, cstate, shared, key, wire, static, mode):
        """-> (cstate', recon_wire, stats int32 (client_stats_len,))."""
        raise NotImplementedError

    # -- in-jit cross-client reduction / server-side update ----------------
    def reduce_stats(self, stats: jnp.ndarray) -> jnp.ndarray:
        """(C, client_stats_len) -> (stats_len,) int32."""
        return jnp.zeros((0,), jnp.int32)

    def update_shared(self, shared, reduced_stats, mean_wire):
        return shared

    # -- host side ---------------------------------------------------------
    def per_client_key(self, base_key, client):
        """Per-(round, client, group) randomness; ``client`` may be traced."""
        return jax.random.fold_in(jax.random.fold_in(base_key, client),
                                  self.path_idx)

    def init_static(self):
        """Initial per-round static config (hashable; None if unused)."""
        return None

    def next_static(self, reduced: np.ndarray, static):
        """Host rule updating the static config from fetched stats."""
        return static

    def charge_bits(self, reduced: np.ndarray, n_sel: int, static) -> int:
        """Exact uplink bits for ``n_sel`` clients this round (Python int)."""
        raise NotImplementedError

    def host_metrics(self, reduced: np.ndarray, n_sel: int, static) -> Dict[str, int]:
        """Optional per-round host-side metric increments (e.g. sum_d)."""
        return {}


# ---------------------------------------------------------------------------
# per-tensor baselines: flat (n,) wire layout
# ---------------------------------------------------------------------------

class _FlatCodec(Codec):
    """Shared flat-vector layout for the per-tensor baselines."""

    def __init__(self, n: int, path_idx: int = 0):
        super().__init__(path_idx)
        self.n = int(n)

    def to_wire(self, delta: jnp.ndarray) -> jnp.ndarray:
        return delta.reshape(-1).astype(jnp.float32)


class TopKCodec(_FlatCodec):
    """Magnitude top-k with per-client error memory (ref [23]).

    Wire: k values + k int32 indices -> 2k * 32 bits per client.
    """

    def __init__(self, n: int, frac: float = 0.1, path_idx: int = 0):
        super().__init__(n, path_idx)
        self.k = max(1, int(frac * self.n))

    def init_client_state(self, n_clients: int, client_ids=None):
        return jnp.zeros((n_clients, self.n), jnp.float32)

    def encode(self, cstate, shared, key, wire, static, mode):
        st, ghat, _ = bl.topk_compress(bl.TopKState(cstate), wire, self.k)
        return st.memory, ghat, jnp.zeros((0,), jnp.int32)

    def charge_bits(self, reduced, n_sel, static):
        return 32 * 2 * self.k * n_sel


class FedPAQCodec(_FlatCodec):
    """Stochastic uniform quantization (ref [21]).

    ``use_pallas=False``: the paper's global-max-abs scale
    (``core.baselines.quantize_stochastic``) -- n*bits + one 32-bit scale.
    ``use_pallas=True``: the TPU-native block-local quantizer
    (``kernels/quant.py`` via the ``kernels.ops`` dispatch) -- n*bits plus
    one 32-bit scale per ``block`` entries.
    """

    def __init__(self, n: int, bits: int = 8, path_idx: int = 0,
                 use_pallas: bool = False,
                 pallas_interpret: Optional[bool] = None, block: int = 512):
        super().__init__(n, path_idx)
        self.bits = int(bits)
        self.use_pallas = bool(use_pallas)
        self.pallas_interpret = pallas_interpret
        self.block = int(block)

    def _quantize(self, g, key):
        from repro.kernels.ops import quantize_update

        return quantize_update(
            g, key, bits=self.bits, block=self.block,
            use_pallas=self.use_pallas, interpret=self.pallas_interpret,
        )

    def encode(self, cstate, shared, key, wire, static, mode):
        return (), self._quantize(wire, key), jnp.zeros((0,), jnp.int32)

    @property
    def _n_scales(self) -> int:
        return -(-self.n // self.block) if self.use_pallas else 1

    def charge_bits(self, reduced, n_sel, static):
        return (self.n * self.bits + 32 * self._n_scales) * n_sel


class SignSGDCodec(_FlatCodec):
    """1-bit sign compression with a mean-magnitude scale (ref [20])."""

    def encode(self, cstate, shared, key, wire, static, mode):
        ghat, _ = bl.sign_compress(wire)
        return (), ghat, jnp.zeros((0,), jnp.int32)

    def charge_bits(self, reduced, n_sel, static):
        return (self.n + 32) * n_sel


class FedQClipCodec(FedPAQCodec):
    """Clipped + quantized updates (ref [42]); same wire as FedPAQ."""

    def __init__(self, n: int, clip: float = 100.0, bits: int = 8,
                 path_idx: int = 0, use_pallas: bool = False,
                 pallas_interpret: Optional[bool] = None, block: int = 512):
        super().__init__(n, bits, path_idx, use_pallas, pallas_interpret, block)
        self.clip = float(clip)

    def encode(self, cstate, shared, key, wire, static, mode):
        norm = jnp.linalg.norm(wire)
        clipped = wire * jnp.minimum(1.0, self.clip / jnp.maximum(norm, 1e-12))
        return (), self._quantize(clipped, key), jnp.zeros((0,), jnp.int32)


# ---------------------------------------------------------------------------
# matrix-layout codecs: stacked (L, l, m) segment matrices
# ---------------------------------------------------------------------------

class _MatrixCodec(Codec):
    """Shared (L, l, m) segment-matrix layout (``columns = segments``)."""

    def __init__(self, plan: LayerPlan, path_idx: int = 0):
        super().__init__(path_idx)
        self.plan = plan

    def to_wire(self, delta: jnp.ndarray) -> jnp.ndarray:
        plan = self.plan
        flat = delta.reshape(plan.stack, -1)
        m = plan.n // plan.l
        return (flat.reshape(plan.stack, m, plan.l)
                .swapaxes(-1, -2).astype(jnp.float32))

    def from_wire(self, wire: jnp.ndarray, shape) -> jnp.ndarray:
        plan = self.plan
        flat = wire.swapaxes(-1, -2).reshape(plan.stack, plan.n)
        return flat.reshape(shape)


class SVDFedCodec(_MatrixCodec):
    """Globally shared per-group basis (ref [12]), round-granular refits.

    The shared basis M lives server-side; clients upload coefficients
    ``A = M^T G`` between refits.  A *refit round* ships raw G from every
    client (full uplink, SVDFed's calibration cost) and the server re-fits
    M from the aggregated gradient in-jit.  The refit decision is taken at
    round granularity: if any client's relative fitting error exceeds
    ``gamma``% this round, the *next* round is a refit round.  (The old
    host-dict implementation flipped mid-round in client-iteration order,
    which no client-symmetric vmap can reproduce; round granularity is the
    deterministic formulation both engines share.)  Round 0 is always a
    refit round (M starts empty).
    """

    #: stats: [is_refit_round, wants_refit_next]
    client_stats_len = 2
    stats_len = 2

    def __init__(self, plan: LayerPlan, gamma: float = 8.0, seed: int = 0,
                 path_idx: int = 0):
        super().__init__(plan, path_idx)
        self.gamma = float(gamma)
        self.seed = int(seed)

    def init_shared_state(self):
        plan = self.plan
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 17),
                                 self.path_idx)
        return (jnp.zeros((plan.stack, plan.l, plan.k), jnp.float32),
                key, jnp.ones((), jnp.bool_))

    def encode(self, cstate, shared, key, wire, static, mode):
        M, _, refit = shared
        A = jnp.einsum("xlk,xlm->xkm", M, wire)
        Ghat = jnp.einsum("xlk,xkm->xlm", M, A)
        recon = jnp.where(refit, wire, Ghat)
        err = jnp.sum((wire - Ghat).astype(jnp.float32) ** 2)
        den = jnp.maximum(jnp.sum(wire.astype(jnp.float32) ** 2), 1e-30)
        thresh = (self.gamma / 100.0) ** 2
        want = jnp.logical_and(~refit, err > thresh * den)
        stats = jnp.stack([refit, want]).astype(jnp.int32)
        return (), recon, stats

    def reduce_stats(self, stats):
        return jnp.max(stats, axis=0).astype(jnp.int32)

    def update_shared(self, shared, reduced_stats, mean_wire):
        M, key, refit = shared
        key2, sub = jax.random.split(key)

        def _fit(_):
            subs = jax.random.split(sub, self.plan.stack)
            return jax.vmap(
                lambda g, kk: randomized_svd(kk, g, rank=self.plan.k)[0]
            )(mean_wire, subs)

        M2 = jax.lax.cond(refit, _fit, lambda _: M, operand=None)
        return (M2, key2, reduced_stats[1] > 0)

    def charge_bits(self, reduced, n_sel, static):
        plan = self.plan
        if int(reduced[0]):                       # refit round: raw uplink
            return 32 * plan.raw_scalars * n_sel
        return 32 * plan.k * plan.m * plan.stack * n_sel


class GradESTCCodec(_MatrixCodec):
    """The paper's spatio-temporal compressor (Algorithms 1-2).

    Per-client state: basis stack ``(L, l, k)``, rSVD key stack ``(L, 2)``,
    per-layer init flags ``(L,)`` -- stacked to ``(C, ...)`` by the engine.
    ``static`` is the rSVD candidate count ``d`` (XLA needs a static sketch
    shape); ``next_static`` is Formula 13 on the round's max d_r, bucketed
    to powers of two.  ``mode`` statically selects the branch structure:

    * ``"init"``   -- every selected client uninitialized (round 0).
    * ``"update"`` -- every selected client initialized (the steady state).
    * ``"mixed"``  -- stragglers under partial participation; keeps the
      ``lax.cond`` (a vmapped cond lowers to a select that executes both
      branches, i.e. a full extra rSVD -- affordable only on mixed rounds).

    Stats per client: ``[max d_r over updating layers, #layers on the init
    branch... (as n_upd = #updating layers), sum d_r]`` -- reduced across
    clients to ``[drmax, n_upd, sum_dr]``, from which the host rebuilds
    Formula 14 in exact integer arithmetic.
    """

    client_stats_len = 3
    stats_len = 3

    def __init__(self, plan: LayerPlan, seed: int = 0, path_idx: int = 0,
                 variant: str = "full", alpha: float = 1.3, beta: float = 1.0,
                 use_pallas: bool = False,
                 pallas_interpret: Optional[bool] = None):
        assert variant in ("full", "first", "all", "k")
        super().__init__(plan, path_idx)
        self.seed = int(seed)
        self.variant = variant
        self.alpha, self.beta = float(alpha), float(beta)
        self.use_pallas = bool(use_pallas)
        self.pallas_interpret = pallas_interpret

    @property
    def has_init_branch(self) -> bool:           # "all" re-inits every round
        return self.variant != "all"

    @property
    def dynamic_static(self) -> bool:            # Formula 13 moves d buckets
        return self.variant == "full"

    def init_client_state(self, n_clients: int, client_ids=None):
        plan = self.plan
        L, l, k = plan.stack, plan.l, plan.k
        ids = (jnp.arange(n_clients) if client_ids is None
               else jnp.asarray(client_ids, jnp.uint32))
        return (
            jnp.zeros((n_clients, L, l, k), jnp.float32),
            jax.vmap(lambda c: client_layer_keys(self.seed, c, self.path_idx, L))(ids),
            jnp.zeros((n_clients, L), jnp.bool_),
        )

    def _layer_step(self, d: int, mode: str):
        k = self.plan.k
        # Decode (Ghat = M A) takes the same use_pallas switch as encode:
        # server-side reconstruction and the downlink decode path both run
        # through the blocked Pallas decode kernel (interpret off-TPU).
        recon = functools.partial(ge.reconstruct, use_pallas=self.use_pallas,
                                  pallas_interpret=self.pallas_interpret)

        def _init(st, G):
            st2, payload, stats = ge.compress_init(st, G, k=k)
            return (st2.M, st2.key, recon(st2.M, payload.coeffs),
                    stats.d_r, jnp.ones((), jnp.bool_))

        def _update(st, G):
            st2, payload, stats = ge.compress_update(
                st, G, k=k, d=d, use_pallas=self.use_pallas,
                pallas_interpret=self.pallas_interpret,
            )
            return (st2.M, st2.key, recon(st2.M, payload.coeffs),
                    stats.d_r, jnp.zeros((), jnp.bool_))

        def _project(st, G):
            # GradESTC-first ablation: frozen basis, coefficients only.
            A = st.M.T @ G
            return (st.M, st.key, recon(st.M, A),
                    jnp.zeros((), jnp.int32), jnp.zeros((), jnp.bool_))

        steady = _project if self.variant == "first" else _update

        def step(M, key, initialized, G):
            st = ge.CompressorState(M=M, key=key, initialized=initialized)
            if self.variant == "all" or mode == "init":
                return _init(st, G)
            if mode == "update":
                return steady(st, G)
            return jax.lax.cond(initialized, steady, _init, st, G)

        return step

    def encode(self, cstate, shared, key, wire, static, mode):
        M, keys, inited = cstate
        step = self._layer_step(static, mode)
        M2, K2, Ghat, d_r, was_init = jax.vmap(step)(M, keys, inited, wire)
        # d_r on update branches only; inits (d_r == k) are reported via the
        # n_upd count instead, so the host can reconstruct Formula 14 in
        # exact integer arithmetic.
        upd_dr = jnp.where(was_init, 0, d_r).astype(jnp.int32)
        stats = jnp.stack([
            jnp.max(upd_dr),
            jnp.sum(~was_init).astype(jnp.int32),
            jnp.sum(upd_dr),
        ])
        return (M2, K2, jnp.ones_like(inited)), Ghat, stats

    def reduce_stats(self, stats):
        return jnp.stack([
            jnp.max(stats[:, 0]), jnp.sum(stats[:, 1]), jnp.sum(stats[:, 2]),
        ]).astype(jnp.int32)

    def init_static(self):
        k = self.plan.k
        return k if self.variant == "k" else max(1, k // 4)

    def next_static(self, reduced, static):
        drmax, n_upd = int(reduced[0]), int(reduced[1])
        if self.variant == "full" and n_upd > 0:
            return ge.next_candidate_count(drmax, self.plan.k,
                                           self.alpha, self.beta)
        return static

    def charge_bits(self, reduced, n_sel, static):
        plan = self.plan
        n_upd, sum_dr = int(reduced[1]), int(reduced[2])
        n_init = n_sel * plan.stack - n_upd
        # Formula 14: inits ship the basis (k*l) + coefficients; updates
        # ship coefficients + the d_r entering vectors and their indices.
        return 32 * (n_init * (plan.k * plan.l + plan.k * plan.m)
                     + n_upd * plan.k * plan.m
                     + sum_dr * (plan.l + 1))

    def host_metrics(self, reduced, n_sel, static):
        # Computational-overhead proxy (Table IV): every init pays a rank-k
        # sketch, every update a rank-d sketch (d only spent for full / k).
        n_upd = int(reduced[1])
        n_init = n_sel * self.plan.stack - n_upd
        inc = self.plan.k * n_init
        if self.variant in ("full", "k"):
            inc += int(static) * n_upd
        return {"sum_d": inc}


class EFCodec(Codec):
    """Error-feedback wrapper (paper Sec. VI / beyond-paper ``-ef``):
    client memory accumulates the compression residual in wire layout and
    re-injects it before the inner encode."""

    def __init__(self, inner: Codec, mem_shape: Tuple[int, ...]):
        super().__init__(inner.path_idx)
        self.inner = inner
        self.mem_shape = tuple(int(s) for s in mem_shape)
        self.client_stats_len = inner.client_stats_len
        self.stats_len = inner.stats_len

    @property
    def has_init_branch(self) -> bool:
        return self.inner.has_init_branch

    @property
    def dynamic_static(self) -> bool:
        return self.inner.dynamic_static

    def init_client_state(self, n_clients: int, client_ids=None):
        return (self.inner.init_client_state(n_clients, client_ids),
                jnp.zeros((n_clients,) + self.mem_shape, jnp.float32))

    def init_shared_state(self):
        return self.inner.init_shared_state()

    def to_wire(self, delta):
        return self.inner.to_wire(delta)

    def from_wire(self, wire, shape):
        return self.inner.from_wire(wire, shape)

    def encode(self, cstate, shared, key, wire, static, mode):
        inner_st, mem = cstate
        injected = wire + mem
        inner_st2, recon, stats = self.inner.encode(
            inner_st, shared, key, injected, static, mode)
        return (inner_st2, injected - recon), recon, stats

    def reduce_stats(self, stats):
        return self.inner.reduce_stats(stats)

    def update_shared(self, shared, reduced_stats, mean_wire):
        return self.inner.update_shared(shared, reduced_stats, mean_wire)

    def init_static(self):
        return self.inner.init_static()

    def next_static(self, reduced, static):
        return self.inner.next_static(reduced, static)

    def charge_bits(self, reduced, n_sel, static):
        return self.inner.charge_bits(reduced, n_sel, static)

    def host_metrics(self, reduced, n_sel, static):
        return self.inner.host_metrics(reduced, n_sel, static)
