"""repro.core -- GradESTC: spatio-temporal gradient compression for FL.

Public API surface of the paper's contribution:

  * reshaping   -- WHDC flatten + (l, m) segmentation (Sec. III-A.a)
  * rsvd        -- randomized SVD (Halko et al.), the paper's decomposition tool
  * gradestc    -- compressor / decompressor pair (Algorithms 1-2)
  * policy      -- parameter-dominant layer selection and (k, l) assignment
  * baselines   -- Top-k / FedPAQ / signSGD / SVDFed / FedQClip comparators
  * codecs      -- the stateless functional codec protocol every method
                   implements (vmappable encode + exact integer-bit
                   accounting; DESIGN.md Sec. 9)
  * error_feedback -- EF memory (paper Sec. VI future work; beyond-paper)
  * metrics     -- exact uplink/downlink byte accounting
"""

from . import baselines, codecs, error_feedback, gradestc, metrics, policy, reshaping, rsvd
from .gradestc import (
    CompressorState,
    DecompressorState,
    Payload,
    CompressStats,
    compress,
    compress_init,
    compress_step,
    compress_update,
    decompress,
    init_compressor,
    next_candidate_count,
    next_candidate_count_jax,
)
from .policy import CompressionPolicy, LayerPlan, make_policy
from .reshaping import matrix_to_tensor, reshape_to_matrix, segment, unsegment
from .rsvd import randomized_svd

__all__ = [
    "baselines", "codecs", "error_feedback", "gradestc", "metrics", "policy",
    "reshaping", "rsvd",
    "CompressorState", "DecompressorState", "Payload", "CompressStats",
    "compress", "compress_init", "compress_step", "compress_update",
    "decompress", "init_compressor", "next_candidate_count",
    "next_candidate_count_jax",
    "CompressionPolicy", "LayerPlan", "make_policy",
    "matrix_to_tensor", "reshape_to_matrix", "segment", "unsegment",
    "randomized_svd",
]
