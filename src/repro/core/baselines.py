"""Baseline uplink compressors the paper compares against (Table III).

Every baseline implements the same functional interface over a *flat* gradient
vector ``g in R^n``::

    state = <Name>State.init(n, ...)
    state, ghat, scalars = <name>_compress(state, g, key)

``ghat`` is the server-side reconstruction (what enters aggregation) and
``scalars`` the number of 32-bit-equivalent scalars transmitted uplink
(fractional for sub-32-bit codes), so methods are compared in bytes exactly
as the paper does.

Implemented:
  * FedAvg       -- identity (no compression), the uncompressed reference.
  * Top-k        -- magnitude sparsification with error accumulation
                    (Stich et al., ref [23]).
  * FedPAQ       -- stochastic uniform quantization to 2^b levels
                    (Reisizadeh et al., ref [21]).
  * signSGD      -- 1-bit sign compression with scale (Bernstein et al. [20]).
  * SVDFed       -- shared low-rank basis from the aggregated gradient,
                    clients upload coefficients; basis re-fit when the fitting
                    error degrades past a threshold (Wang et al., ref [12]).
  * FedQClip     -- clipped SGD + uniform quantization (Qu et al., ref [42]).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .rsvd import randomized_svd

__all__ = [
    "TopKState", "topk_compress",
    "QuantState", "fedpaq_compress", "quantize_stochastic", "dequantize",
    "sign_compress",
    "SVDFedState", "svdfed_client_compress", "svdfed_server_refresh",
    "fedqclip_compress",
]


# --------------------------------------------------------------------------
# Top-k sparsification with error memory
# --------------------------------------------------------------------------

class TopKState(NamedTuple):
    memory: jnp.ndarray        # (n,) error accumulation

    @staticmethod
    def init(n: int, dtype=jnp.float32) -> "TopKState":
        return TopKState(memory=jnp.zeros((n,), dtype))


def topk_compress(
    state: TopKState, g: jnp.ndarray, k: int
) -> Tuple[TopKState, jnp.ndarray, jnp.ndarray]:
    """Keep the k largest-magnitude entries of (g + memory)."""
    corrected = g + state.memory
    vals, idx = jax.lax.top_k(jnp.abs(corrected), k)
    ghat = jnp.zeros_like(corrected).at[idx].set(corrected[idx])
    new_mem = corrected - ghat
    # transmitted: k values + k int32 indices
    scalars = jnp.asarray(2 * k, jnp.float32)
    return TopKState(memory=new_mem), ghat, scalars


# --------------------------------------------------------------------------
# Stochastic uniform quantization (FedPAQ)
# --------------------------------------------------------------------------

class QuantState(NamedTuple):
    """FedPAQ is stateless; kept for interface uniformity."""

    @staticmethod
    def init(n: int = 0) -> "QuantState":
        return QuantState()


def quantize_stochastic(
    g: jnp.ndarray, key: jax.Array, bits: int = 8
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Unbiased stochastic uniform quantizer on [-scale, scale].

    Returns (codes int32 in [0, 2^bits-1], scale).
    """
    levels = (1 << bits) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    x = (g / scale + 1.0) * (levels / 2.0)          # [0, levels]
    lo = jnp.floor(x)
    p_up = x - lo
    up = jax.random.bernoulli(key, p_up, g.shape)
    codes = jnp.clip(lo + up.astype(g.dtype), 0, levels).astype(jnp.int32)
    return codes, scale


def dequantize(codes: jnp.ndarray, scale: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    levels = (1 << bits) - 1
    return (codes.astype(jnp.float32) * (2.0 / levels) - 1.0) * scale


def fedpaq_compress(
    state: QuantState, g: jnp.ndarray, key: jax.Array, bits: int = 8
) -> Tuple[QuantState, jnp.ndarray, jnp.ndarray]:
    codes, scale = quantize_stochastic(g, key, bits)
    ghat = dequantize(codes, scale, bits).astype(g.dtype)
    scalars = jnp.asarray(g.size * bits / 32.0 + 1.0, jnp.float32)
    return state, ghat, scalars


# --------------------------------------------------------------------------
# signSGD
# --------------------------------------------------------------------------

def sign_compress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.mean(jnp.abs(g))
    ghat = jnp.sign(g) * scale
    scalars = jnp.asarray(g.size / 32.0 + 1.0, jnp.float32)
    return ghat, scalars


# --------------------------------------------------------------------------
# SVDFed: globally shared basis, coefficient-only uplink between refreshes
# --------------------------------------------------------------------------

class SVDFedState(NamedTuple):
    M: jnp.ndarray             # (l, k) shared basis (server-fit)
    err_threshold: jnp.ndarray # () refit when relative error exceeds this
    initialized: jnp.ndarray   # () bool

    @staticmethod
    def init(l: int, k: int, gamma: float = 8.0, dtype=jnp.float32) -> "SVDFedState":
        # gamma follows the paper's SVDFed hyperparameter: larger gamma ->
        # tolerate more error before a (costly) basis re-fit.
        return SVDFedState(
            M=jnp.zeros((l, k), dtype),
            err_threshold=jnp.asarray(gamma / 100.0, jnp.float32),
            initialized=jnp.zeros((), jnp.bool_),
        )


def svdfed_client_compress(
    state: SVDFedState, G: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Client: upload coefficients A = M^T G; flags a refresh request if the
    fitting error is too large.  Returns (A, rel_err, scalars)."""
    A = state.M.T @ G
    E = G - state.M @ A
    rel = jnp.sqrt(jnp.sum(E * E) / jnp.maximum(jnp.sum(G * G), 1e-30))
    scalars = jnp.asarray(A.size, jnp.float32)
    return A, rel, scalars


def svdfed_server_refresh(
    state: SVDFedState, G_agg: jnp.ndarray, key: jax.Array, k: int
) -> SVDFedState:
    """Server: re-fit the shared basis from the aggregated gradient matrix."""
    U, _, _ = randomized_svd(key, G_agg, rank=k)
    return state._replace(M=U, initialized=jnp.ones((), jnp.bool_))


# --------------------------------------------------------------------------
# FedQClip: clipping + quantization
# --------------------------------------------------------------------------

def fedqclip_compress(
    g: jnp.ndarray, key: jax.Array, clip: float, bits: int = 8
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    norm = jnp.linalg.norm(g)
    g_clipped = g * jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    codes, scale = quantize_stochastic(g_clipped, key, bits)
    ghat = dequantize(codes, scale, bits).astype(g.dtype)
    scalars = jnp.asarray(g.size * bits / 32.0 + 1.0, jnp.float32)
    return ghat, scalars
