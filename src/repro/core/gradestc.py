"""GradESTC compressor / decompressor (paper Algorithms 1 and 2).

Pure-functional JAX implementation.  A compressor-decompressor *pair* exists
per compressed layer group; its state is the orthonormal basis ``M`` shared
(by construction) between client and server.

Key departures from the PyTorch pseudocode, required by XLA (documented in
DESIGN.md "Assumptions changed"):

* The number of SVD candidates ``d`` is a **traced** value over rank-padded
  buffers (:func:`compress_step`): the rSVD sketch always runs at the static
  capacity ``d_max`` (= k, the Formula-13 clamp) and candidates beyond the
  traced ``d`` are masked out of the top-k scoring, so the paper's dynamic
  rule ``d* = min(alpha*d_r + beta, k)`` runs *in-jit*
  (:func:`next_candidate_count_jax`) with no recompilation when ``d`` moves
  between rounds.  The legacy static-``d`` entry points
  (:func:`compress_update`, host-side :func:`next_candidate_count` with its
  power-of-two buckets) are kept as the reference semantics the padded step
  is property-tested against.

* The wire payload uses a fixed-capacity buffer of ``d`` replacement vectors
  with a validity count ``d_r``; byte accounting (``metrics.py``) charges only
  the ``d_r`` valid entries, matching the paper's
  ``C = k*m + d_r*l + k`` (Formula 14).

* Everything is written over a leading *group* axis so that one ``vmap``
  covers all layers of a stack (and another covers clients).

The replacement rule (Formulas 11-12): stack coefficients
``A_oe = [A; A_e]``, score each basis vector by its squared coefficient row
norm ``R_u = ||A_oe[u, :]||^2``, keep the top-k rows.  Old columns that fall
out of the top-k are overwritten *in place* (index set P) by the entering
candidates in index order.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .rsvd import randomized_svd

__all__ = [
    "CompressorState",
    "DecompressorState",
    "Payload",
    "CompressStats",
    "init_compressor",
    "compress_init",
    "compress_update",
    "compress_step",
    "compress",
    "decompress",
    "apply_payload",
    "reconstruct",
    "next_candidate_count",
    "next_candidate_count_jax",
    "payload_scalars",
]


class CompressorState(NamedTuple):
    """Client-side state for one compressed layer group."""

    M: jnp.ndarray          # (l, k) orthonormal basis
    key: jax.Array          # PRNG key for randomized SVD
    initialized: jnp.ndarray  # () bool


class DecompressorState(NamedTuple):
    """Server-side mirror of the basis."""

    M: jnp.ndarray          # (l, k)


class Payload(NamedTuple):
    """What crosses the uplink for one layer group in one round.

    ``new_vectors``/``replaced_mask`` encode the paper's (P, M-set); entries
    beyond ``d_r`` are zero and never read by the decompressor.
    """

    replaced_mask: jnp.ndarray   # (k,) bool -- True where M[:, j] is replaced
    new_vectors: jnp.ndarray     # (d, l)   -- entering basis vectors, rank order
    coeffs: jnp.ndarray          # (k, m)   -- updated combination coefficients A*
    d_r: jnp.ndarray             # ()       -- number of valid replacement vectors
    init: jnp.ndarray            # () bool  -- True on the initialization round


class CompressStats(NamedTuple):
    d_r: jnp.ndarray             # () int32 number of replaced basis vectors
    recon_err: jnp.ndarray       # () relative Frobenius reconstruction error
    energy_kept: jnp.ndarray     # () ||M M^T G||_F^2 / ||G||_F^2  (= chi_k^2)


def init_compressor(l: int, k: int, key: jax.Array, dtype=jnp.float32) -> CompressorState:
    return CompressorState(
        M=jnp.zeros((l, k), dtype),
        key=key,
        initialized=jnp.zeros((), jnp.bool_),
    )


def _stats(G: jnp.ndarray, Ghat: jnp.ndarray, d_r: jnp.ndarray) -> CompressStats:
    gnorm = jnp.sum(G.astype(jnp.float32) ** 2)
    err = jnp.sum((G - Ghat).astype(jnp.float32) ** 2)
    safe = jnp.maximum(gnorm, 1e-30)
    return CompressStats(
        d_r=d_r.astype(jnp.int32),
        recon_err=jnp.sqrt(err / safe),
        energy_kept=1.0 - err / safe,
    )


def compress_init(
    state: CompressorState, G: jnp.ndarray, *, k: int
) -> Tuple[CompressorState, Payload, CompressStats]:
    """First-round compression (Alg. 1 lines 2-8): basis from rSVD of G."""
    l, m = G.shape
    key, sub = jax.random.split(state.key)
    U, S, Vt = randomized_svd(sub, G, rank=k)
    M = U                                    # (l, k)
    A = S[:, None] * Vt                      # == M^T G for exact SVD
    payload = Payload(
        replaced_mask=jnp.ones((k,), jnp.bool_),
        new_vectors=M.T,                     # all k vectors ship on round 0
        coeffs=A,
        d_r=jnp.asarray(k, jnp.int32),
        init=jnp.ones((), jnp.bool_),
    )
    new_state = CompressorState(M=M, key=key, initialized=jnp.ones((), jnp.bool_))
    return new_state, payload, _stats(G, M @ A, jnp.asarray(k))


def compress_update(
    state: CompressorState, G: jnp.ndarray, *, k: int, d: int,
    use_pallas: bool = False, pallas_interpret: bool | None = None,
) -> Tuple[CompressorState, Payload, CompressStats]:
    """Steady-state compression (Alg. 1 lines 9-29).

    ``d`` (number of candidate vectors from the fitting error) is static.

    ``use_pallas`` routes the spatial projection + residual (``A = M^T G``,
    ``E = G - M A`` -- the hot path feeding the rSVD) through the fused
    Pallas kernel (``kernels/gradestc_encode.py``), which streams ``G``
    from HBM once instead of twice; ``pallas_interpret=True`` runs the
    kernel body in interpret mode (the CPU fallback).  Both are static
    trace-time switches.
    """
    l, m = G.shape
    M = state.M
    key, sub = jax.random.split(state.key)

    # --- spatial projection onto the carried-over basis -------------------
    if use_pallas:
        from repro.kernels.ops import encode

        A, E = encode(M, G, interpret=pallas_interpret)  # Formulas 4 + 6 fused
    else:
        A = M.T @ G                               # (k, m)   Formula 4
        E = G - M @ A                             # (l, m)   Formula 6

    # --- candidates from the fitting error (orthogonal to M by Formula 9) -
    Ue, Se, Vte = randomized_svd(sub, E, rank=d)
    Me = Ue                                       # (l, d)
    Ae = Se[:, None] * Vte                        # (d, m) == Me^T E == Me^T G

    # --- contribution scores and top-k retention (Formulas 11-12) ---------
    R_old = jnp.sum(A.astype(jnp.float32) ** 2, axis=1)    # (k,)
    R_new = jnp.sum(Ae.astype(jnp.float32) ** 2, axis=1)   # (d,)
    R = jnp.concatenate([R_old, R_new])                    # (k+d,)
    #

    # membership of the top-k by value, ties broken toward old vectors
    # (old indices come first in R, jax.lax.top_k is stable on index order).
    _, top_idx = jax.lax.top_k(R, k)
    in_top = jnp.zeros((k + d,), jnp.bool_).at[top_idx].set(True)

    replaced = ~in_top[:k]                        # (k,) old columns leaving
    entering = in_top[k:]                         # (d,) candidates entering
    d_r = jnp.sum(entering).astype(jnp.int32)     # == jnp.sum(replaced)

    # Pair the i-th replaced slot with the i-th entering candidate.
    repl_rank = jnp.cumsum(replaced.astype(jnp.int32)) - 1        # (k,)
    # entering candidate indices in index order, packed to the front:
    enter_order = jnp.argsort(~entering, stable=True)             # (d,)
    src = enter_order[jnp.clip(repl_rank, 0, d - 1)]              # (k,)

    M_new = jnp.where(replaced[None, :], Me[:, src], M)           # (l, k)
    A_new = jnp.where(replaced[:, None], Ae[src, :], A)           # (k, m)

    # Wire buffer: entering vectors packed in rank order, zero padded.
    enter_rank = jnp.cumsum(entering.astype(jnp.int32)) - 1       # (d,)
    buf = jnp.zeros((d, l), M.dtype)
    buf = buf.at[jnp.where(entering, enter_rank, d)].set(
        Me.T, mode="drop"
    )

    payload = Payload(
        replaced_mask=replaced,
        new_vectors=buf,
        coeffs=A_new,
        d_r=d_r,
        init=jnp.zeros((), jnp.bool_),
    )
    new_state = CompressorState(M=M_new, key=key, initialized=state.initialized)
    return new_state, payload, _stats(G, M_new @ A_new, d_r)


def compress_step(
    state: CompressorState, G: jnp.ndarray, *, k: int, d,
    d_max: int | None = None,
    use_pallas: bool = False, pallas_interpret: bool | None = None,
    wire_dtype: str = "f32",
) -> Tuple[CompressorState, Payload, CompressStats]:
    """Branch-free rank-padded compression step with a **traced** ``d``.

    One code path serves every round: the rSVD sketch always runs at the
    static capacity ``d_max`` (default ``k`` -- Formula 13's clamp, so the
    padded buffers cover every reachable ``d``), and candidates at index
    ``>= d`` are masked out of the top-k scoring with a ``-inf`` score, which
    reproduces the static-``d`` replacement rule exactly (the masked
    candidates can never enter, and ties/ordering among the first ``d`` are
    untouched -- ``tests/test_round_engine.py`` pins this for every
    ``d in [0, d_max]``).

    The initialization round is the *same* path: an uninitialized state
    carries ``M = 0``, so ``A = 0``, ``E = G`` exactly, and forcing
    ``R_old = -inf`` / ``d_eff = d_max`` makes all ``k`` rSVD vectors of G
    enter -- bit-identical to :func:`compress_init` when ``d_max == k``.
    This is what lets a K-round ``lax.scan`` body run init, steady-state,
    and mixed partial-participation rounds without a static ``mode`` or a
    vmapped ``lax.cond`` (which would execute both branches anyway).

    ``payload.new_vectors`` is the fixed ``(d_max, l)`` wire buffer; entries
    beyond ``d_r`` are zero and byte accounting charges only the ``d_r``
    valid ones (Formula 14), so the rank padding never touches the ledger.

    ``wire_dtype`` selects the *coefficient* wire format ("f32" exact ship,
    "bf16" half-word pairs, "int8" per-(row, 512-block)-scaled codes --
    DESIGN.md "Wire-format layer").  The roundtrip applies to ``A_new``
    *after* basis replacement -- coefficients pass through the replacement
    pairing between projection and wire, so unlike SVDFed's steady state the
    quantization here cannot fuse into the projection kernel.  The shipped
    value feeds both the payload and the stats, so client and server agree
    on the reconstruction exactly.  Basis vectors always ship f32: client
    and server mirror the basis from them, and a lossy basis would drift the
    two copies apart.
    """
    l, m = G.shape
    d_max = k if d_max is None else d_max
    key, sub = jax.random.split(state.key)
    init = ~state.initialized                       # () bool, may be traced
    # An initializing layer projects against the zero basis, so A = 0 and
    # E = G *exactly* -- a fresh client state already carries M = 0, and
    # masking here extends the same guarantee to forced re-inits (the
    # GradESTC-all ablation) whose carried basis is non-zero.
    M = jnp.where(init, jnp.zeros_like(state.M), state.M)

    # --- spatial projection onto the carried-over basis -------------------
    if use_pallas:
        from repro.kernels.ops import encode

        A, E = encode(M, G, interpret=pallas_interpret)  # Formulas 4 + 6 fused
    else:
        A = M.T @ G                                  # (k, m)   Formula 4
        E = G - M @ A                                # (l, m)   Formula 6

    # --- rank-padded candidates: always sketch at d_max, mask the tail ----
    d_eff = jnp.where(init, d_max, d).astype(jnp.int32)
    Ue, Se, Vte = randomized_svd(sub, E, rank=d_max)
    Me = Ue                                          # (l, d_max)
    Ae = Se[:, None] * Vte                           # (d_max, m)

    neg = jnp.asarray(-jnp.inf, jnp.float32)
    R_old = jnp.where(init, neg,
                      jnp.sum(A.astype(jnp.float32) ** 2, axis=1))   # (k,)
    valid = jnp.arange(d_max) < d_eff
    R_new = jnp.where(valid,
                      jnp.sum(Ae.astype(jnp.float32) ** 2, axis=1), neg)
    R = jnp.concatenate([R_old, R_new])              # (k + d_max,)

    # membership of the top-k by value, ties broken toward old vectors
    # (old indices first, jax.lax.top_k is stable on index order; masked
    # candidates sit at -inf and can never displace a finite old score).
    _, top_idx = jax.lax.top_k(R, k)
    in_top = jnp.zeros((k + d_max,), jnp.bool_).at[top_idx].set(True)

    replaced = ~in_top[:k]                           # (k,) old columns leaving
    entering = in_top[k:]                            # (d_max,) cands entering
    d_r = jnp.sum(entering).astype(jnp.int32)

    # Pair the i-th replaced slot with the i-th entering candidate.
    repl_rank = jnp.cumsum(replaced.astype(jnp.int32)) - 1          # (k,)
    enter_order = jnp.argsort(~entering, stable=True)               # (d_max,)
    src = enter_order[jnp.clip(repl_rank, 0, d_max - 1)]            # (k,)

    M_new = jnp.where(replaced[None, :], Me[:, src], M)             # (l, k)
    A_new = jnp.where(replaced[:, None], Ae[src, :], A)             # (k, m)

    if wire_dtype != "f32":
        from repro.kernels.ops import coeff_roundtrip

        A_new = coeff_roundtrip(A_new, wire_dtype, use_kernel=use_pallas,
                                interpret=pallas_interpret)

    # Wire buffer: entering vectors packed in rank order, zero padded.
    enter_rank = jnp.cumsum(entering.astype(jnp.int32)) - 1
    buf = jnp.zeros((d_max, l), M.dtype)
    buf = buf.at[jnp.where(entering, enter_rank, d_max)].set(
        Me.T, mode="drop"
    )

    payload = Payload(
        replaced_mask=replaced,
        new_vectors=buf,
        coeffs=A_new,
        d_r=d_r,
        init=init,
    )
    new_state = CompressorState(M=M_new, key=key,
                                initialized=jnp.ones((), jnp.bool_))
    return new_state, payload, _stats(G, M_new @ A_new, d_r)


def compress(
    state: CompressorState, G: jnp.ndarray, *, k: int, d: int,
    use_pallas: bool = False, pallas_interpret: bool | None = None,
) -> Tuple[CompressorState, Payload, CompressStats, jnp.ndarray]:
    """Dispatch between init and update based on ``state.initialized``.

    Both branches are traced (lax.cond) so the function is jit-stable across
    rounds.  Returns ``(state, payload, stats, basis)`` where ``basis`` is the
    full updated M -- only meaningful (and only *transmitted*) on the init
    round.  The FL runtime avoids gathering it in steady state by using
    :func:`compress_init` for round 0 and :func:`compress_update` afterwards;
    this cond-based variant exists for single-jit multi-round loops and tests.
    """

    def _init(st):
        st2, p, s = compress_init(st, G, k=k)
        # pad/crop the init payload to the (d, l) update buffer layout; the
        # full basis additionally travels in the `basis` slot (charged once
        # by the byte accounting).
        nv = jnp.zeros((d, G.shape[0]), st.M.dtype)
        nv = nv.at[: min(d, k)].set(p.new_vectors[: min(d, k)])
        return st2, Payload(p.replaced_mask, nv, p.coeffs, p.d_r, p.init), s, st2.M

    def _update(st):
        st2, p, s = compress_update(st, G, k=k, d=d, use_pallas=use_pallas,
                                    pallas_interpret=pallas_interpret)
        return st2, p, s, st2.M

    new_state, payload, stats, basis = jax.lax.cond(
        state.initialized, _update, _init, state
    )
    return new_state, payload, stats, basis


def decompress(
    state: DecompressorState, payload: Payload, init_basis: jnp.ndarray | None = None,
    *, use_pallas: bool = False, pallas_interpret: bool | None = None,
) -> Tuple[DecompressorState, jnp.ndarray]:
    """Server side (Alg. 2): update the mirrored basis, reconstruct G-hat.

    ``use_pallas`` routes the reconstruction GEMM through the decode kernel
    (``kernels/gradestc_decode.py``) -- the same static switch the encode
    path takes, interpret fallback off-TPU."""
    M = state.M
    k = M.shape[1]
    d = payload.new_vectors.shape[0]

    repl_rank = jnp.cumsum(payload.replaced_mask.astype(jnp.int32)) - 1
    src = jnp.clip(repl_rank, 0, d - 1)
    M_upd = jnp.where(
        payload.replaced_mask[None, :], payload.new_vectors[src].T, M
    )
    if init_basis is not None:
        M_upd = jnp.where(payload.init, init_basis, M_upd)
    Ghat = reconstruct(M_upd, payload.coeffs, use_pallas=use_pallas,
                       pallas_interpret=pallas_interpret)
    return DecompressorState(M=M_upd), Ghat


def apply_payload(state: DecompressorState, payload: Payload) -> DecompressorState:
    new_state, _ = decompress(state, payload)
    return new_state


def reconstruct(
    M: jnp.ndarray, A: jnp.ndarray, *, use_pallas: bool = False,
    pallas_interpret: bool | None = None,
) -> jnp.ndarray:
    """Ghat = M A (Alg. 2 line 2) -- the decode half of the codec.

    ``use_pallas`` dispatches to the blocked Pallas decode kernel via
    ``kernels.ops.decode`` (compiled on TPU, interpret mode elsewhere); the
    default stays the plain XLA GEMM."""
    if use_pallas:
        from repro.kernels.ops import decode

        return decode(M, A, interpret=pallas_interpret)
    return M @ A


def next_candidate_count_jax(d_r, k: int, alpha: float = 1.3,
                             beta: float = 1.0) -> jnp.ndarray:
    """Formula 13 as traced int32 arithmetic: ``d* = min(ceil(alpha*d_r +
    beta), k)``, clamped to at least 1.

    No power-of-two bucketing: the rank-padded step (:func:`compress_step`)
    keeps every buffer at ``d_max``, so a moving ``d`` no longer recompiles
    anything -- the paper's exact rule runs in-jit every round (the host
    :func:`next_candidate_count` with its buckets remains only for the
    legacy static-``d`` path)."""
    d = jnp.ceil(alpha * jnp.asarray(d_r, jnp.float32) + beta)
    return jnp.clip(d.astype(jnp.int32), 1, k)


def next_candidate_count(
    d_r: int, k: int, alpha: float = 1.3, beta: float = 1.0, bucket: bool = True
) -> int:
    """Host-side dynamic adjustment of ``d`` (Formula 13), bucketed to powers
    of two to bound XLA recompilations."""
    d = min(int(math.ceil(alpha * d_r + beta)), k)
    d = max(d, 1)
    if bucket:
        d = 1 << (d - 1).bit_length()   # next power of two
        d = min(d, k)
    return d


def payload_scalars(payload: Payload, *, l: int, m: int, k: int, bytes_per_el: int = 4):
    """Paper Formula 14: actual uplink scalars for this payload.

    init round: full basis (k*l) + coefficients (k*m)
    update round: coefficients (k*m) + d_r basis vectors (d_r*l) + d_r indices
    """
    init_cost = k * l + k * m
    upd_cost = k * m + payload.d_r * l + payload.d_r
    scalars = jnp.where(payload.init, init_cost, upd_cost)
    return scalars * bytes_per_el
