"""Error-feedback memory (paper Sec. VI, listed as future work).

Classic EF-SGD (Karimireddy et al. 2019 style): the client accumulates the
compression residual and adds it back before the next compression::

    c_t    = Compress(g_t + m_t)
    m_t+1  = g_t + m_t - Decompress(c_t)

For GradESTC the residual is exactly the fitting error ``E`` reshaped back to
the flat gradient, so EF integrates with zero extra compute: we feed
``G + M_seg`` (segmented memory) into the compressor and store the new
fitting error as memory.

This is a *beyond-paper* extension (flagged in DESIGN.md Sec. 7) and is off by
default; EXPERIMENTS.md quantifies its effect separately from the faithful
reproduction.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

__all__ = ["EFState", "ef_inject", "ef_update"]


class EFState(NamedTuple):
    memory: jnp.ndarray     # same shape as the segmented gradient matrix G

    @staticmethod
    def init(l: int, m: int, dtype=jnp.float32) -> "EFState":
        return EFState(memory=jnp.zeros((l, m), dtype))


def ef_inject(state: EFState, G: jnp.ndarray, decay: float = 1.0) -> jnp.ndarray:
    """Gradient handed to the compressor: G + decayed residual memory."""
    return G + decay * state.memory.astype(G.dtype)


def ef_update(state: EFState, G_injected: jnp.ndarray, Ghat: jnp.ndarray) -> EFState:
    """Store the new residual (exactly the compressor's fitting error)."""
    return EFState(memory=(G_injected - Ghat).astype(state.memory.dtype))
