"""Randomized SVD (Halko, Martinsson & Tropp 2011) in pure JAX.

The paper uses randomized SVD both to initialize the basis (Alg. 1 line 3)
and to extract candidate basis vectors from the fitting error (line 12),
because a full SVD of the reshaped gradient matrix is too expensive for
resource-constrained FL clients (Sec. III-C.b cites the
``O(log(d) l m + d^2 (l + m))`` complexity of rSVD).

Implementation notes
--------------------
* Pure function of an explicit PRNG key -- safe under jit/vmap/pjit.
* ``q`` power iterations with QR re-orthonormalization for spectral-gap
  robustness (q=1 default; q=0 matches the paper's complexity model).
* Oversampling ``p`` (default 8) per Halko et al. recommendation.
* Shapes are static; ``rank`` must be a Python int at trace time.
* All matmuls are MXU-shaped (tall-skinny); QR/SVD of the small core matrix
  goes through XLA's native decompositions.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["randomized_svd", "randomized_range_finder"]


def randomized_range_finder(
    key: jax.Array,
    A: jnp.ndarray,
    size: int,
    n_iter: int = 1,
) -> jnp.ndarray:
    """Approximate an orthonormal basis Q for the range of ``A`` (l x m).

    Returns ``Q in R^{l x size}`` with orthonormal columns such that
    ``A ~= Q Q^T A``.
    """
    l, m = A.shape
    omega = jax.random.normal(key, (m, size), dtype=A.dtype)
    Y = A @ omega                                   # (l, size)
    Q, _ = jnp.linalg.qr(Y)
    for _ in range(n_iter):                         # power iterations
        Z, _ = jnp.linalg.qr(A.T @ Q)               # (m, size)
        Q, _ = jnp.linalg.qr(A @ Z)                 # (l, size)
    return Q


@partial(jax.jit, static_argnames=("rank", "n_oversample", "n_iter"))
def randomized_svd(
    key: jax.Array,
    A: jnp.ndarray,
    rank: int,
    n_oversample: int = 8,
    n_iter: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Truncated randomized SVD: ``A ~= U[:, :rank] diag(S[:rank]) Vt[:rank]``.

    Args:
      key: PRNG key for the Gaussian test matrix.
      A: (l, m) matrix.
      rank: number of singular triplets to return (static).
      n_oversample: extra random directions for accuracy.
      n_iter: power iterations (0 = plain sketch).

    Returns:
      (U, S, Vt) with shapes (l, rank), (rank,), (rank, m).

    The GradESTC hot path computes this over the fitting-error residual
    ``E = G - M A`` that the fused Pallas encode kernel produces in the same
    HBM pass as the coefficients (``core/gradestc.compress_update``); the
    projections *inside* the sketch deliberately stay plain GEMMs -- the
    fused kernel would also emit an (l, m) residual the sketch discards,
    costing an extra GEMM plus an l*m write for nothing.
    """
    l, m = A.shape
    size = min(rank + n_oversample, m, l)
    # Compute in f32 for numerical stability even if gradients are bf16.
    A32 = A.astype(jnp.float32)
    Q = randomized_range_finder(key, A32, size, n_iter)   # (l, size)
    B = Q.T @ A32                                         # (size, m) small
    Ub, S, Vt = jnp.linalg.svd(B, full_matrices=False)
    U = Q @ Ub                                            # (l, size)
    return (
        U[:, :rank].astype(A.dtype),
        S[:rank].astype(A.dtype),
        Vt[:rank, :].astype(A.dtype),
    )
