"""Per-layer compression policy: which layers to compress and with what (k, l).

The paper (Sec. I / V-A.b) compresses only *parameter-dominant* layers --
layers holding the large majority of model parameters (99.0% for LeNet5,
92.3% for ResNet18, 98.7% for AlexNet in the paper's setups) -- because
temporal correlation is empirically strongest there, and because the smaller
remaining layers contribute negligible uplink anyway.

For the assigned transformer-family architectures the parameter-dominant
layers are the per-layer projection matrices (attention qkv/o, FFN in/out,
MoE expert banks); embeddings / norms / biases / routers stay uncompressed.

(k, l) follow the paper's rule: ``l ~= sqrt(n)`` aligned with structural
boundaries, ``k << l`` chosen per layer group.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Mapping, Tuple

import numpy as np

from .reshaping import choose_segment_length

__all__ = ["LayerPlan", "CompressionPolicy", "make_policy", "coverage"]


@dataclass(frozen=True)
class LayerPlan:
    """Compression plan for one (stacked) parameter group."""

    name: str
    shape: Tuple[int, ...]       # per-layer tensor shape (without stack axis)
    stack: int                   # number of stacked layers sharing this plan
    l: int                       # segment length (rows of G)
    m: int                       # columns of G
    k: int                       # retained basis vectors
    compress: bool               # False -> transmitted raw

    @property
    def n(self) -> int:
        return int(np.prod(self.shape))

    @property
    def d_max(self) -> int:
        """Static capacity of the rank-padded dynamic-``d`` buffers: Formula
        13 clamps ``d* = min(ceil(alpha*d_r + beta), k)``, so ``k`` covers
        every reachable candidate count.  All per-round payload/state for
        this group is allocated at ``d_max`` and masked by the traced per-
        round ``d_r`` (``core/gradestc.compress_step``) -- this is what keeps
        the round program's shapes static while ``d`` moves."""
        return self.k

    @property
    def raw_scalars(self) -> int:
        return self.n * self.stack

    def update_scalars(self, d_r: int) -> int:
        """Formula 14 per stacked layer."""
        return (self.k * self.m + d_r * self.l + d_r) * self.stack

    @property
    def init_scalars(self) -> int:
        return (self.k * self.l + self.k * self.m) * self.stack


@dataclass
class CompressionPolicy:
    plans: Dict[str, LayerPlan] = field(default_factory=dict)
    min_params_to_compress: int = 65536   # tiny tensors ship raw
    coverage_target: float = 0.90        # parameter-dominant threshold

    def plan_for(self, name: str) -> LayerPlan | None:
        return self.plans.get(name)


def _default_k(n: int, l: int) -> int:
    """k << l, scaled gently with matrix size (paper uses 4..48 across layers
    of 0.26MB..218MB models, and k=32 for all ResNet18 layers)."""
    m = n // l
    k = max(4, min(l // 8, m // 4, 64))
    # round down to a power of two for MXU-friendly tile sizes
    return 1 << (k.bit_length() - 1) if k & (k - 1) else k


#: Name fragments never compressed: embeddings (row-sparse gradients defeat
#: low-rank structure), norms/biases/scales (tiny), MoE routers (tiny but
#: convergence-critical -- see DESIGN.md Sec. 4).
DEFAULT_EXCLUDE = ("embed", "norm", "bias", "router", "scale", "ln_", "head")


def make_policy(
    param_shapes: Mapping[str, Tuple[Tuple[int, ...], int]],
    overrides: Mapping[str, Tuple[int, int]] | None = None,
    coverage_target: float = 0.90,
    min_params: int = 65536,
    exclude: Tuple[str, ...] = DEFAULT_EXCLUDE,
) -> CompressionPolicy:
    """Build a policy from ``{group_name: (per_layer_shape, stack)}``.

    Groups are sorted by total parameter count; the largest groups are marked
    for compression until ``coverage_target`` of all parameters is covered
    (the paper's parameter-dominant selection), subject to ``min_params`` and
    the ``exclude`` name fragments.  ``overrides`` maps group name -> (k, l).
    """
    overrides = dict(overrides or {})
    totals = {
        name: int(np.prod(shape)) * stack
        for name, (shape, stack) in param_shapes.items()
    }
    grand = sum(totals.values()) or 1
    order = sorted(totals, key=totals.get, reverse=True)

    plans: Dict[str, LayerPlan] = {}
    covered = 0
    for name in order:
        shape, stack = param_shapes[name]
        n = int(np.prod(shape))
        excluded = any(frag in name.lower() for frag in exclude)
        want = (
            covered / grand < coverage_target
            and n >= min_params
            and len(shape) >= 2
            and not excluded
        )
        if name in overrides:
            k, l = overrides[name]
            want = True
        elif want:
            l = choose_segment_length(shape)
            k = _default_k(n, l)
        else:
            l, k = max(1, int(shape[-1])) if n % max(1, int(shape[-1])) == 0 else 1, 0
        if want:
            covered += totals[name]
        plans[name] = LayerPlan(
            name=name, shape=tuple(int(s) for s in shape), stack=stack,
            l=l, m=n // l, k=k, compress=bool(want),
        )
    return CompressionPolicy(plans=plans, coverage_target=coverage_target,
                             min_params_to_compress=min_params)


def coverage(policy: CompressionPolicy) -> float:
    """Fraction of parameters covered by compressed groups."""
    tot = sum(p.raw_scalars for p in policy.plans.values()) or 1
    cov = sum(p.raw_scalars for p in policy.plans.values() if p.compress)
    return cov / tot
