"""Communication accounting -- exact uplink/downlink byte bookkeeping.

The paper's headline numbers (Table III) are uplink GB at a target accuracy
and total uplink GB.  This module provides a tiny ledger used by the FL
runtime and the benchmarks so every method is charged identically:

  * payload scalars are converted at ``bytes_per_scalar`` (4 for fp32 wire
    format, 2 for bf16) -- sub-word codes (quantization, signs) report
    fractional scalars;
  * per-round, per-client, per-layer-group resolution;
  * uplink  = client -> server (gradient direction);
    downlink = server -> client (model broadcast), counted once per round as
    the full model unless downlink compression is enabled.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

__all__ = ["CommLedger", "bytes_h", "host_fetch", "host_sync_count", "reset_host_sync_count"]


#: Device->host transfer counter.  Every blocking fetch in the FL runtime is
#: routed through :func:`host_fetch` so benchmarks can *measure* the per-round
#: host-sync count instead of asserting it by inspection (DESIGN.md Sec. 8:
#: the fused round engine's contract is exactly one fetch per round).
_HOST_SYNCS = 0


def host_fetch(x):
    """Materialize a device value on the host, counting the sync."""
    global _HOST_SYNCS
    _HOST_SYNCS += 1
    import numpy as _np

    return _np.asarray(x)


def host_sync_count() -> int:
    return _HOST_SYNCS


def reset_host_sync_count() -> None:
    global _HOST_SYNCS
    _HOST_SYNCS = 0


def bytes_h(b: float) -> str:
    """Human-readable bytes."""
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024.0 or unit == "TB":
            return f"{b:.3f} {unit}"
        b /= 1024.0
    return f"{b:.3f} TB"


@dataclass
class CommLedger:
    bytes_per_scalar: float = 4.0
    uplink_total: float = 0.0
    downlink_total: float = 0.0
    per_round_uplink: List[float] = field(default_factory=list)
    per_group: Dict[str, float] = field(default_factory=dict)

    def begin_round(self) -> None:
        self.per_round_uplink.append(0.0)

    def charge_uplink(self, scalars: float, group: str = "_",
                      round_idx: int | None = None) -> None:
        """Charge ``scalars`` of uplink.  ``round_idx`` pins the charge to an
        explicit round slot -- required by the pipelined fused engine, which
        defers the stats fetch for round r until after round r+1 has begun
        (so "the last slot" is no longer round r's slot)."""
        b = float(scalars) * self.bytes_per_scalar
        self.uplink_total += b
        if round_idx is not None:
            if not 0 <= round_idx < len(self.per_round_uplink):
                raise IndexError(
                    f"charge_uplink round_idx={round_idx} but only "
                    f"{len(self.per_round_uplink)} rounds begun")
            self.per_round_uplink[round_idx] += b
        elif self.per_round_uplink:
            self.per_round_uplink[-1] += b
        self.per_group[group] = self.per_group.get(group, 0.0) + b

    def charge_downlink(self, scalars: float) -> None:
        self.downlink_total += float(scalars) * self.bytes_per_scalar

    @property
    def rounds(self) -> int:
        return len(self.per_round_uplink)

    def uplink_at(self, round_idx: int) -> float:
        """Cumulative uplink bytes through round ``round_idx`` (inclusive)."""
        return sum(self.per_round_uplink[: round_idx + 1])

    def summary(self) -> str:
        lines = [
            f"uplink total   : {bytes_h(self.uplink_total)}",
            f"downlink total : {bytes_h(self.downlink_total)}",
            f"rounds         : {self.rounds}",
        ]
        if self.per_group:
            lines.append("per-group uplink:")
            for g, b in sorted(self.per_group.items(), key=lambda kv: -kv[1]):
                lines.append(f"  {g:40s} {bytes_h(b)}")
        return "\n".join(lines)
