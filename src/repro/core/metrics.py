"""Communication accounting -- exact uplink/downlink byte bookkeeping.

The paper's headline numbers (Table III) are uplink GB at a target accuracy
and total uplink GB.  This module provides a tiny ledger used by the FL
runtime and the benchmarks so every method is charged identically:

  * totals accumulate as **exact integer bits** (``charge_uplink_bits`` /
    ``charge_downlink_bits`` -- the codecs' ``charge_bits`` contract), so
    no float rounding can skew Table III totals at any scale; sub-word
    codes (quantization, signs) are integral in bits even when fractional
    in scalars.  The byte-valued views (``uplink_total`` & co.) divide by 8
    on read -- dyadic rationals, exact in f64;
  * per-round and per-group resolution;
  * uplink  = client -> server (gradient direction);
    downlink = server -> client (model broadcast), counted once per round as
    the full model unless downlink compression is enabled.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

__all__ = ["CommLedger", "bytes_h", "host_fetch", "host_sync_count", "reset_host_sync_count"]


#: Device->host transfer counter.  Every blocking fetch in the FL runtime is
#: routed through :func:`host_fetch` so benchmarks can *measure* the per-round
#: host-sync count instead of asserting it by inspection (DESIGN.md Sec. 8:
#: the fused round engine's contract is exactly one fetch per round).
_HOST_SYNCS = 0


def host_fetch(x):
    """Materialize a device value on the host, counting the sync."""
    global _HOST_SYNCS
    _HOST_SYNCS += 1
    import numpy as _np

    return _np.asarray(x)


def host_sync_count() -> int:
    return _HOST_SYNCS


def reset_host_sync_count() -> None:
    global _HOST_SYNCS
    _HOST_SYNCS = 0


def bytes_h(b: float) -> str:
    """Human-readable bytes."""
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024.0 or unit == "TB":
            return f"{b:.3f} {unit}"
        b /= 1024.0
    return f"{b:.3f} TB"


@dataclass
class CommLedger:
    uplink_bits: int = 0
    downlink_bits: int = 0
    per_round_uplink_bits: List[int] = field(default_factory=list)
    per_group_bits: Dict[str, int] = field(default_factory=dict)

    def begin_round(self) -> None:
        self.per_round_uplink_bits.append(0)

    def charge_uplink_bits(self, bits: int, group: str = "_",
                           round_idx: int | None = None) -> None:
        """Charge exact integer ``bits`` of uplink.  ``round_idx`` pins the
        charge to an explicit round slot -- required by the chunked fused
        engine, which consumes a whole K-round stats block after round
        ``start+K-1`` has begun (so "the last slot" is not round r's)."""
        bits = int(bits)
        self.uplink_bits += bits
        if round_idx is not None:
            if not 0 <= round_idx < len(self.per_round_uplink_bits):
                raise IndexError(
                    f"charge_uplink round_idx={round_idx} but only "
                    f"{len(self.per_round_uplink_bits)} rounds begun")
            self.per_round_uplink_bits[round_idx] += bits
        elif self.per_round_uplink_bits:
            self.per_round_uplink_bits[-1] += bits
        self.per_group_bits[group] = self.per_group_bits.get(group, 0) + bits

    def charge_downlink_bits(self, bits: int) -> None:
        self.downlink_bits += int(bits)

    # -- byte-valued views (exact: bits are integers, /8 is dyadic) --------
    @property
    def uplink_total(self) -> float:
        return self.uplink_bits / 8

    @property
    def downlink_total(self) -> float:
        return self.downlink_bits / 8

    @property
    def per_round_uplink(self) -> List[float]:
        return [b / 8 for b in self.per_round_uplink_bits]

    @property
    def per_group(self) -> Dict[str, float]:
        return {g: b / 8 for g, b in self.per_group_bits.items()}

    @property
    def rounds(self) -> int:
        return len(self.per_round_uplink_bits)

    def uplink_at(self, round_idx: int) -> float:
        """Cumulative uplink bytes through round ``round_idx`` (inclusive)."""
        return sum(self.per_round_uplink_bits[: round_idx + 1]) / 8

    def summary(self) -> str:
        lines = [
            f"uplink total   : {bytes_h(self.uplink_total)}",
            f"downlink total : {bytes_h(self.downlink_total)}",
            f"rounds         : {self.rounds}",
        ]
        if self.per_group:
            lines.append("per-group uplink:")
            for g, b in sorted(self.per_group.items(), key=lambda kv: -kv[1]):
                lines.append(f"  {g:40s} {bytes_h(b)}")
        return "\n".join(lines)
