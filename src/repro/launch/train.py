"""Federated training driver.

Two modes:

  * ``--mode sim`` (default): the benchmark-scale FL loop (repro.fl) -- real
    learning on the synthetic LM task with exact uplink accounting; runs on
    whatever devices exist (CPU in this container).

  * ``--mode spmd``: the production SPMD round step (the same function the
    dry-run lowers) executed on a local mesh with a reduced architecture --
    end-to-end proof that the distributed round actually steps, not only
    compiles.

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode sim --method gradestc --rounds 30
  PYTHONPATH=src python -m repro.launch.train --mode spmd --arch gemma3-1b --rounds 3
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import time


def _run_sim(args) -> int:
    from repro.fl import FLConfig, run_fl

    cfg = FLConfig(
        method=args.method,
        rounds=args.rounds,
        n_clients=args.clients,
        local_steps=args.local_steps,
        alpha=args.alpha,
        lr=args.lr,
        seed=args.seed,
        eval_every=max(1, args.rounds // 10),
    )

    def progress(rnd, info):
        print(f"round {rnd:4d} loss={info['loss']:.4f} acc={info['acc']:.4f} "
              f"uplink={info['uplink']/2**20:.2f}MiB", flush=True)

    res = run_fl(cfg, progress=progress)
    print("---")
    print(res.ledger.summary())
    print(f"final loss {res.eval_loss[-1]:.4f}  acc {res.eval_acc[-1]:.4f}  "
          f"wall {res.wall_s:.1f}s")
    return 0


def _run_spmd(args) -> int:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.data import client_batch_stream, make_task
    from repro.launch.mesh import make_local_mesh
    from repro.launch.sharding import make_plan, param_specs
    from repro.launch.steps import (
        compression_policy_for, make_fl_round_step, make_ge_state,
        ge_state_specs,
    )
    from repro.models import model

    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(cfg, vocab=256)
    n_dev = len(jax.devices())
    mesh = make_local_mesh((n_dev, 1), ("data", "model"))
    plan = make_plan(mesh, cfg)
    policy = compression_policy_for(cfg, plan)
    C = plan.n_clients

    step = make_fl_round_step(cfg, mesh, plan, policy, method=args.method,
                              lr=args.lr, local_steps=args.local_steps)
    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    ge_state = make_ge_state(cfg, policy, C, seed=args.seed)
    step_j = jax.jit(step)

    task = make_task(vocab=cfg.vocab, n_clients=C, alpha=args.alpha, seed=args.seed)
    streams = [client_batch_stream(task, c, args.batch, args.seq, args.seed)
               for c in range(C)]
    evalb = next(client_batch_stream(task, -1, args.batch, args.seq, 77))

    @jax.jit
    def eval_loss(p, b):
        from repro.models import loss_fn
        return loss_fn(cfg, p, b)

    for rnd in range(args.rounds):
        t0 = time.time()
        bs = [next(s) for s in streams]
        batches = {k: jnp.stack([b[k] for b in bs]) for k in bs[0]}
        params, ge_state, metrics = step_j(params, ge_state, batches)
        l = float(eval_loss(params, evalb))
        print(f"round {rnd}: eval_loss={l:.4f}  ({time.time()-t0:.1f}s)", flush=True)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=["sim", "spmd"], default="sim")
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--method", default="gradestc")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--local-steps", dest="local_steps", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=None,
                    help="Dirichlet non-IID (0.5/0.1); default IID")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compilation-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache dir (default: "
                    "$JAX_COMPILATION_CACHE_DIR or ~/.cache/"
                    "repro_jax_compilation)")
    args = ap.parse_args(argv)
    # Host tuning first: XLA_FLAGS and logging knobs are frozen at the
    # first jax import, which happens inside _run_sim/_run_spmd.
    from repro.launch.env import configure_host

    configure_host(verbose=True)
    # Persistent compile cache: repeat training invocations skip XLA
    # compilation of the chunk/step executables entirely.
    from repro.launch.compile_cache import enable_compilation_cache

    enable_compilation_cache(args.compilation_cache)
    if args.mode == "sim":
        return _run_sim(args)
    return _run_spmd(args)


if __name__ == "__main__":
    raise SystemExit(main())
