"""Production mesh construction.

Single pod:  (data=16, model=16)            = 256 chips (one v5e pod slice)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips

Defined as functions (never module-level constants) so importing this module
never touches JAX device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls these.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh(shape=(1, 1), axes=("data", "model")) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests / CPU smoke)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


class HW:
    """TPU v5e hardware model used for the roofline terms (EXPERIMENTS.md)."""

    PEAK_FLOPS_BF16 = 197e12       # per chip
    HBM_BW = 819e9                 # bytes/s per chip
    ICI_BW = 50e9                  # bytes/s per link
    HBM_BYTES = 16 * 1024**3       # per chip
