"""Production mesh construction.

Single pod:  (data=16, model=16)            = 256 chips (one v5e pod slice)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips

Defined as functions (never module-level constants) so importing this module
never touches JAX device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls these.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_fl_mesh", "HW"]


def _mk_mesh(shape, axes) -> jax.sharding.Mesh:
    # jax.sharding.AxisType / make_mesh(axis_types=...) only exist on newer
    # jax; every mesh here is Auto-typed anyway, so fall back cleanly.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk_mesh(shape, axes)


def make_local_mesh(shape=(1, 1), axes=("data", "model")) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests / CPU smoke)."""
    return _mk_mesh(shape, axes)


def make_fl_mesh(n_devices: int) -> jax.sharding.Mesh:
    """Mesh for the sharded fused FL round (``fl/engine.py``): the selected-
    client axis shards over ``"data"``; ``"model"`` stays size 1 because the
    single-host engine replicates params (tensor parallelism inside the
    vmapped local-train step lives in ``launch/steps.py``, not here).

    On CPU, force the device count *before* any jax import with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    avail = len(jax.devices())
    if n_devices > avail:
        raise ValueError(
            f"mesh wants {n_devices} devices but only {avail} exist "
            "(on CPU set XLA_FLAGS=--xla_force_host_platform_device_count)")
    return make_local_mesh((n_devices, 1), ("data", "model"))


class HW:
    """TPU v5e hardware model used for the roofline terms (EXPERIMENTS.md)."""

    PEAK_FLOPS_BF16 = 197e12       # per chip
    HBM_BW = 819e9                 # bytes/s per chip
    ICI_BW = 50e9                  # bytes/s per link
    HBM_BYTES = 16 * 1024**3       # per chip
