import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination on placeholder devices and extract the roofline terms.

The two lines above MUST stay the first statements of this module (before
any jax import): jax locks the device count at first initialization.

Per (arch, shape, mesh) this produces:
  * PROOF   -- the true-depth, scan-compact, sharded program compiles;
              memory_analysis() shows the per-device footprint.
  * COST    -- flops / bytes / per-collective bytes, extracted from two
              reduced-depth *unrolled* lowerings with identical shardings
              and linearly extrapolated to the true depth:
                  per_layer = (cost(2p) - cost(p)) / p
                  total     = cost(p) + per_layer * (L - p)
              (lax.scan bodies are counted once by cost_analysis -- verified
              in this container -- so the cost lowerings unroll; the proof
              lowering keeps the scan.  DESIGN.md Sec. 6.)
  * ROOFLINE -- compute / memory / collective seconds on the v5e model
              (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI per chip).

Results append to a JSON report consumed by benchmarks/roofline.py and
EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--method gradestc]
  python -m repro.launch.dryrun --all --proof-only      # fast shardability pass
"""

import argparse
import dataclasses
import functools
import json
import re
import time
import traceback
from collections import Counter
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import arch_names, get_config, get_shape, is_skipped
from repro.models import model, param_group_shapes
from repro.models.config import ArchConfig, InputShape

from .mesh import HW, make_production_mesh
from .sharding import (
    MeshPlan, batch_specs, cache_specs, make_plan, param_specs,
    client_stacked_specs, axis_size,
)
from .steps import (
    GEState, compression_policy_for, ge_state_specs, make_fl_round_step,
    make_ge_state, make_serve_steps, serve_input_specs, train_input_specs,
)

__all__ = ["dryrun_pair", "main"]

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


# --------------------------------------------------------------------------
# HLO parsing
# --------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _dtype_bytes(name: str) -> int:
    return {
        "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
        "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
        "f8e4m3fn": 1, "f8e5m2": 1,
    }.get(name, 4)


def _first_shape_bytes(sig: str) -> int:
    """Sum the sizes of all array shapes in an HLO result signature."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _dtype_bytes(dt)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in (post-SPMD) HLO.

    These are *global* bytes (the named shapes are per-device outputs times
    they appear once per device program -- we report per-device bytes, which
    is what the ICI roofline term wants)."""
    out: Dict[str, float] = Counter()
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?\S+\s*=\s*(\([^)]*\)|\S+)\s+(\S+)\(", ls)
        if not m:
            continue
        op = m.group(2).split(".")[0]
        if op in _COLLECTIVES:
            out[op] += _first_shape_bytes(m.group(1))
    return dict(out)


_CONVERT_DEF_RE = re.compile(
    r"%wrapped_convert[\w.]*\s*\(param[\w.]*:\s*bf16\[([0-9,]+)\]\)\s*->\s*f32\[\1\]"
)


def cpu_f32_artifact_bytes(hlo_text: str, floor: int = 1 << 26) -> int:
    """Bytes of whole-tensor bf16->f32 converts the CPU backend inserts to
    legalize bf16 dots (hoisted out of layer scans as persistent f32 copies
    of weight stacks / KV caches).  A TPU build computes these dots natively
    in mixed precision, so the proof lowering's memory_analysis over-counts
    by roughly this amount; reported separately (DESIGN.md Sec. 6)."""
    total = 0
    for m in _CONVERT_DEF_RE.finditer(hlo_text):
        n = 1
        for d in m.group(1).split(","):
            n *= int(d)
        if n * 4 >= floor:
            total += n * 4
    return total


def _cost_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}


# --------------------------------------------------------------------------
# per-pair lowering
# --------------------------------------------------------------------------

def _reduced_depth(cfg: ArchConfig) -> int:
    """Smallest faithful depth: one full layer pattern (>= 1)."""
    return max(len(cfg.pattern), 1)


def _with_depth(cfg: ArchConfig, L: int, *, unroll: bool, cost_mode: bool) -> ArchConfig:
    kw: Dict[str, Any] = dict(n_layers=L, scan_unroll=L if unroll else 1)
    if cfg.family == "encdec":
        kw["encoder_layers"] = L
    if cost_mode:
        # unroll the inner chunk scans (flash-pattern attention, chunked CE)
        # so cost_analysis counts every chunk; the memory access pattern and
        # remat recompute stay exactly as production.
        kw["attn_unroll"] = True
    return dataclasses.replace(cfg, **kw)


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def _eval_shape_tree(fn, *args):
    return jax.eval_shape(fn, *args)


def _named_tree(mesh, spec_tree, shape_tree):
    return jax.tree.map(
        lambda s, _: NamedSharding(mesh, s),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _auto_grad_accum(cfg: ArchConfig, shape: InputShape, plan: MeshPlan,
                     budget: float = 2e9) -> int:
    """Microbatch count bounding per-device activation-checkpoint memory
    (~ n_layers x tokens_per_device x d_model x 2B per microbatch)."""
    C = plan.n_clients
    B_c = max(shape.global_batch // C, 1)
    inner = 1
    for a in plan.inner_batch_axes:
        inner *= axis_size(plan.mesh, a)
    tokens_dev = B_c * shape.seq_len / max(inner, 1)
    save_bytes = cfg.n_layers * tokens_dev * cfg.d_model * 2
    ga = 1
    while save_bytes / ga > budget and ga < B_c:
        ga *= 2
    while B_c % ga:
        ga //= 2
    return max(ga, 1)


def _lower_train(cfg: ArchConfig, shape: InputShape, mesh, plan: MeshPlan,
                 method: str, d_static: int = 16, grad_accum: int | None = None):
    policy = compression_policy_for(cfg, plan)
    if grad_accum is None:
        ga = cfg.grad_accum_override or _auto_grad_accum(cfg, shape, plan)
    else:
        ga = grad_accum
    step = make_fl_round_step(cfg, mesh, plan, policy, method=method,
                              d_static=d_static, grad_accum=ga)
    params_shape = jax.eval_shape(
        functools.partial(model.init_params, cfg), jax.random.PRNGKey(0)
    )
    ge_shape = jax.eval_shape(
        functools.partial(make_ge_state, cfg, policy, plan.n_clients)
    )
    batch_shapes = train_input_specs(cfg, shape, plan)

    p_specs = param_specs(plan, params_shape)
    g_specs = ge_state_specs(plan, policy)
    b_specs = batch_specs(plan, batch_shapes, client_axis=True)
    in_shardings = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                     is_leaf=lambda x: isinstance(x, P)),
        jax.tree.map(lambda s: NamedSharding(mesh, s), g_specs,
                     is_leaf=lambda x: isinstance(x, P)),
        {k: NamedSharding(mesh, s) for k, s in b_specs.items()},
    )
    out_shardings = (
        in_shardings[0], in_shardings[1],
        jax.tree.map(lambda _: NamedSharding(mesh, P()),
                     jax.eval_shape(step, params_shape, ge_shape, batch_shapes)[2]),
    )
    jitted = jax.jit(step, in_shardings=in_shardings, out_shardings=out_shardings)
    return jitted.lower(params_shape, ge_shape, batch_shapes)


def _lower_serve(cfg: ArchConfig, shape: InputShape, mesh, plan: MeshPlan):
    if plan.huge and cfg.attn_chunk > 256:
        # bound the per-chunk score buffer when attention heads cannot
        # shard 16-way (e.g. yi-34b's 56 heads)
        cfg = dataclasses.replace(cfg, attn_chunk=256)
    prefill, decode = make_serve_steps(cfg)
    params_shape = jax.eval_shape(
        functools.partial(model.init_params, cfg), jax.random.PRNGKey(0)
    )
    p_specs = param_specs(plan, params_shape, role="serve")
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                           is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "prefill":
        batch = serve_input_specs(cfg, shape, decode=False)
        b_specs = batch_specs(plan, batch, client_axis=False)
        b_shard = {k: NamedSharding(mesh, s) for k, s in b_specs.items()}
        jitted = jax.jit(prefill, in_shardings=(p_shard, b_shard))
        return jitted.lower(params_shape, batch)

    # decode
    cache_shape = jax.eval_shape(
        functools.partial(model.init_cache, cfg, shape.global_batch, shape.seq_len)
    )
    c_specs = cache_specs(plan, cache_shape, shape.global_batch)
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                           is_leaf=lambda x: isinstance(x, P))
    tokens = serve_input_specs(cfg, shape, decode=True)
    t_specs = batch_specs(plan, tokens, client_axis=False)
    t_shard = {k: NamedSharding(mesh, s) for k, s in t_specs.items()}
    # logits out-sharding left unconstrained: pinning it to P() would force
    # a (B, V)-sized all-gather that a real server never pays (it samples on
    # the sharded logits).
    jitted = jax.jit(
        decode,
        in_shardings=(p_shard, c_shard, t_shard),
        out_shardings=(None, c_shard),
    )
    return jitted.lower(params_shape, cache_shape, tokens)


def _lower_for(cfg, shape, mesh, plan, method, grad_accum=None):
    if shape.kind == "train":
        return _lower_train(cfg, shape, mesh, plan, method,
                            grad_accum=grad_accum)
    return _lower_serve(cfg, shape, mesh, plan)


def dryrun_pair(
    arch: str, shape_name: str, *, multi_pod: bool = False,
    method: str = "gradestc", proof_only: bool = False,
    verbose: bool = True, cfg_overrides: Optional[Dict[str, Any]] = None,
    tag: str = "",
) -> Dict[str, Any]:
    """Run the full dry-run for one (arch, shape, mesh); returns the record.

    ``cfg_overrides``: dataclasses.replace kwargs applied to the arch config
    -- the SPerf hillclimb switches (EXPERIMENTS.md)."""
    t_start = time.time()
    shape = get_shape(shape_name)
    cfg0 = get_config(arch)
    if cfg_overrides:
        cfg0 = dataclasses.replace(cfg0, **cfg_overrides)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "method": method if shape.kind == "train" else "-",
        "kind": shape.kind, "tag": tag,
        "cfg_overrides": dict(cfg_overrides or {}),
    }
    skip = is_skipped(arch, shape_name)
    if skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    plan = make_plan(mesh, cfg0)
    rec["chips"] = chips
    rec["tp_axes"] = list(plan.tp_axes)
    rec["client_axes"] = list(plan.client_axes)
    rec["n_clients"] = plan.n_clients
    # grad-accum must be derived from the TRUE depth: the reduced-depth
    # cost lowerings would otherwise compute ga=1 and miss the per-
    # microbatch weight re-streaming entirely (EXPERIMENTS.md SPerf).
    ga_true = None
    if shape.kind == "train":
        ga_true = cfg0.grad_accum_override or _auto_grad_accum(cfg0, shape, plan)
        rec["grad_accum"] = ga_true

    # ---- 1. PROOF: true depth, scanned, sharded -------------------------
    t0 = time.time()
    lowered = _lower_for(cfg0, shape, mesh, plan, method, grad_accum=ga_true)
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    ma = compiled.memory_analysis()
    proof_text = compiled.as_text()
    artifact = cpu_f32_artifact_bytes(proof_text)
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "peak_bytes": int(ma.argument_size_in_bytes + ma.temp_size_in_bytes),
        # CPU-backend bf16-dot legalization copies (absent on TPU):
        "cpu_f32_artifact_bytes": artifact,
        "peak_bytes_tpu": int(
            ma.argument_size_in_bytes + ma.temp_size_in_bytes - artifact
        ),
    }
    rec["fits_hbm"] = rec["memory"]["peak_bytes_tpu"] <= HW.HBM_BYTES
    proof_coll = collective_bytes(proof_text)
    rec["proof_collectives"] = proof_coll
    rec["status"] = "ok"
    if proof_only:
        rec["wall_s"] = round(time.time() - t_start, 1)
        return rec

    # ---- 2. COST: reduced-depth unrolled lowerings ----------------------
    p = _reduced_depth(cfg0)
    costs = {}
    colls = {}
    # cap the unrolled grad-accum factor in the cost lowerings (compile-time
    # bound); the residual (ga_true - ga_cost) microbatches re-stream the
    # layer weights ~3x each (fwd + bwd + remat-fwd reads) -- added
    # analytically below.
    ga_cost = min(ga_true, 4) if ga_true else None
    for mult in (1, 2):
        L = p * mult
        cfg_c = _with_depth(cfg0, L, unroll=True, cost_mode=True)
        plan_c = make_plan(mesh, cfg_c)
        lc = _lower_for(cfg_c, shape, mesh, plan_c, method, grad_accum=ga_cost)
        cc = lc.compile()
        costs[mult] = _cost_dict(cc)
        colls[mult] = collective_bytes(cc.as_text())

    L_true = cfg0.n_layers
    def _extrap(key):
        c1 = costs[1].get(key, 0.0)
        c2 = costs[2].get(key, 0.0)
        per_layer = max(c2 - c1, 0.0) / p
        return c1 + per_layer * (L_true - p)

    flops = _extrap("flops")
    bytes_acc = _extrap("bytes accessed")
    if ga_true and ga_cost and ga_true > ga_cost:
        rec["ga_cost"] = ga_cost
        extra_stream = (ga_true - ga_cost) * 3.0 * plan.param_bytes / chips
        rec["ga_stream_correction_bytes"] = extra_stream
        bytes_acc += extra_stream
    coll_total = {}
    for op in set(colls[1]) | set(colls[2]):
        c1, c2 = colls[1].get(op, 0.0), colls[2].get(op, 0.0)
        coll_total[op] = c1 + max(c2 - c1, 0.0) / p * (L_true - p)
    coll_bytes = sum(coll_total.values())

    # cost_analysis on an SPMD-partitioned module reports the *per-device*
    # program (verified empirically: per-device flops x chips ~= analytic
    # global flops), so the roofline terms divide by nothing further.
    rec["hlo_flops_per_device"] = flops
    rec["hlo_bytes_per_device"] = bytes_acc
    rec["collective_bytes_per_device"] = coll_bytes
    rec["collectives"] = coll_total

    # ---- 3. ROOFLINE ------------------------------------------------------
    compute_s = flops / HW.PEAK_FLOPS_BF16
    memory_s = bytes_acc / HW.HBM_BW
    collective_s = coll_bytes / HW.ICI_BW
    rec["roofline"] = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": max(
            ("compute", compute_s), ("memory", memory_s),
            ("collective", collective_s), key=lambda kv: kv[1],
        )[0],
    }

    # MODEL_FLOPS = 6 * N_active * tokens (train: x3 for fwd+bwd handled by
    # the 6 factor; decode: 2 * N_active per token)
    n_active = _active_params(cfg0)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2.0 * n_active * tokens
    rec["model_flops"] = model_flops
    rec["useful_ratio"] = model_flops / (flops * chips) if flops else 0.0
    rec["wall_s"] = round(time.time() - t_start, 1)
    return rec


def _active_params(cfg: ArchConfig) -> float:
    """Parameters touched per token (MoE counts top-k experts only)."""
    total = 0.0
    for name, (shape, stack) in param_group_shapes(cfg).items():
        n = float(np.prod(shape)) * stack
        if "moe_w" in name and cfg.n_experts:
            n *= cfg.experts_per_tok / cfg.n_experts
        if "embed" in name:       # lookup, not matmul
            continue
        total += n
    return total


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def _append_report(path: str, rec: Dict[str, Any]):
    data = []
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data = [r for r in data if not (
        r["arch"] == rec["arch"] and r["shape"] == rec["shape"]
        and r["multi_pod"] == rec["multi_pod"] and r.get("method") == rec.get("method")
    )]
    data.append(rec)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--method", default="gradestc",
                    choices=["gradestc", "fedavg"])
    ap.add_argument("--proof-only", action="store_true")
    ap.add_argument("--report", default="reports/dryrun.json")
    args = ap.parse_args(argv)

    pairs = []
    if args.all:
        from repro.models.config import SHAPES
        for a in arch_names():
            for s in SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
    failures = 0
    for arch, shape in pairs:
        tag = f"{arch} x {shape} ({'2pod' if args.multi_pod else '1pod'})"
        try:
            rec = dryrun_pair(arch, shape, multi_pod=args.multi_pod,
                              method=args.method, proof_only=args.proof_only)
        except Exception as e:  # noqa
            rec = {
                "arch": arch, "shape": shape, "multi_pod": args.multi_pod,
                "method": args.method, "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
            }
            failures += 1
        _append_report(args.report, rec)
        status = rec["status"]
        extra = ""
        if status == "ok":
            mem = rec["memory"]["peak_bytes_tpu"] / 2**30
            extra = f"peak={mem:.2f}GiB fits={rec['fits_hbm']}"
            if "roofline" in rec:
                r = rec["roofline"]
                extra += (f" compute={r['compute_s']*1e3:.1f}ms "
                          f"mem={r['memory_s']*1e3:.1f}ms "
                          f"coll={r['collective_s']*1e3:.1f}ms "
                          f"-> {r['bottleneck']}")
        elif status == "skipped":
            extra = rec["skip_reason"]
        else:
            extra = rec["error"][:200]
        print(f"[{status:7s}] {tag:48s} {extra}", flush=True)
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
