import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Aggregation-collective comparison: FedAvg vs GradESTC (the paper's
uplink, isolated).

The full train-step collective totals are dominated by tensor-parallel
activation all-reduces (identical for both methods).  The FL uplink analog
on the pod is specifically the *cross-client aggregation* collective:
  FedAvg   : all-reduce of the full f32 deltas over the client axis
  GradESTC : all-gather of {A (k x m), new basis vectors (d x l)} payloads
             + shard-local reconstruction
This script lowers both aggregation steps alone at production shapes and
shardings and records their collective bytes -- the datacenter rendering of
the paper's Table III bytes.

Usage: python -m repro.launch.agg_compare [--arch gemma3-1b]
"""

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import arch_names, get_config, get_shape
from repro.core import gradestc as ge

from .dryrun import _cost_dict, collective_bytes
from .mesh import HW, make_production_mesh
from .sharding import make_plan
from .steps import GEState, _delta_to_G, compression_policy_for, ge_state_specs, make_ge_state


def compare(arch: str, d_static: int = 16):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=False)
    plan = make_plan(mesh, cfg)
    C = plan.n_clients
    policy = compression_policy_for(cfg, plan)
    comp = {p: lp for p, lp in policy.plans.items() if lp.compress}

    cl = plan.client_axes
    cspec = cl if len(cl) > 1 else (cl[0] if cl else None)

    delta_shapes = {}
    d_specs = {}
    for p, lp in comp.items():
        shp = (C, lp.stack) + lp.shape
        delta_shapes[p] = jax.ShapeDtypeStruct(shp, jnp.float32)
        d_specs[p] = NamedSharding(mesh, P(cspec, *([None] * (len(shp) - 1))))

    def fedavg_agg(deltas):
        return {p: jnp.mean(v, axis=0) for p, v in deltas.items()}

    def gradestc_agg(ge_state, deltas):
        out = {}
        for p, lp in comp.items():
            G = _delta_to_G(deltas[p], lp)
            def one(Mi, key, Gi):
                st = ge.CompressorState(M=Mi, key=key,
                                        initialized=jnp.ones((), jnp.bool_))
                st2, payload, _ = ge.compress_update(st, Gi, k=lp.k, d=d_static)
                return st2.M, payload.coeffs
            M2, A = jax.vmap(jax.vmap(one))(ge_state.M[p], ge_state.keys[p], G)
            out[p] = jnp.einsum("cxlk,cxkm->xlm", M2, A) / C
        return out

    ge_shape = jax.eval_shape(functools.partial(make_ge_state, cfg, policy, C))
    g_specs = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           ge_state_specs(plan, policy),
                           is_leaf=lambda x: isinstance(x, P))

    rec = {"arch": arch, "n_clients": C}
    for name, fn, args, shardings in (
        ("fedavg", fedavg_agg, (delta_shapes,), (d_specs,)),
        ("gradestc", gradestc_agg, (ge_shape, delta_shapes), (g_specs, d_specs)),
    ):
        cc = jax.jit(fn, in_shardings=shardings).lower(*args).compile()
        coll = collective_bytes(cc.as_text())
        total = sum(coll.values())
        rec[name] = {
            "collective_bytes_per_device": total,
            "collective_s": total / HW.ICI_BW,
            "breakdown": coll,
            "flops": _cost_dict(cc).get("flops", 0.0),
        }
    rec["ratio"] = (
        rec["gradestc"]["collective_bytes_per_device"]
        / max(rec["fedavg"]["collective_bytes_per_device"], 1.0)
    )
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--report", default="reports/agg_compare.json")
    args = ap.parse_args(argv)
    archs = [args.arch] if args.arch else [
        a for a in arch_names()
        if a not in ("dbrx-132b", "qwen2-vl-72b", "yi-34b")  # C=1 single-pod
    ]
    out = []
    for a in archs:
        try:
            rec = compare(a)
        except Exception as e:  # noqa
            rec = {"arch": a, "error": f"{type(e).__name__}: {e}"}
        out.append(rec)
        if "error" in rec:
            print(f"{a:24s} ERROR {rec['error'][:120]}", flush=True)
        else:
            f, g = rec["fedavg"], rec["gradestc"]
            print(f"{a:24s} fedavg={f['collective_bytes_per_device']/2**20:9.1f}MiB "
                  f"({f['collective_s']*1e3:7.1f}ms)  "
                  f"gradestc={g['collective_bytes_per_device']/2**20:9.1f}MiB "
                  f"({g['collective_s']*1e3:7.1f}ms)  ratio={rec['ratio']:.4f}",
                  flush=True)
    os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
    with open(args.report, "w") as fjson:
        json.dump(out, fjson, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
