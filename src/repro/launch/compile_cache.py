"""Persistent XLA compilation cache + compile-time observability.

``enable_compilation_cache`` turns on JAX's on-disk compilation cache so
repeat invocations of the drivers/benchmarks skip XLA compilation entirely
(the scan-fused round engine compiles one executable per chunk shape; with
the cache warm even the first chunk of a fresh process is a disk hit).

``CompileWatcher`` taps ``jax.monitoring`` to count backend compiles and
accumulate the time spent in them -- this is how the round-engine benchmark
splits ``first_round_ms`` into compile vs execute, and how CI asserts the
no-mid-run-recompile contract from *measured* events rather than by
inspection.
"""

from __future__ import annotations

import os
import pathlib
import time
from typing import List, Optional, Tuple

import jax

__all__ = ["enable_compilation_cache", "CompileWatcher"]

_DEFAULT_DIR = os.path.join(
    os.path.expanduser("~"), ".cache", "repro_jax_compilation")

#: monitoring event emitted once per XLA backend compile -- the recompile
#: *count* tracks only these (one per executable built)
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
#: the full compilation pipeline for the compile/execute *time* split:
#: tracing + lowering + backend compile all stall the dispatching host
_PIPELINE_EVENTS = (
    "/jax/core/compile/jaxpr_trace_duration",
    "/jax/core/compile/jaxpr_to_mlir_module_duration",
    "/jax/core/compile/backend_compile_duration",
)


def enable_compilation_cache(cache_dir: Optional[str] = None) -> str:
    """Point JAX's persistent compilation cache at ``cache_dir``.

    Default: ``$JAX_COMPILATION_CACHE_DIR`` or ``~/.cache/repro_jax_
    compilation``.  The min-compile-time threshold is dropped to 0 so even
    the small chunk executables of the scan engine are cached.  Idempotent;
    returns the directory in use.
    """
    cache_dir = (cache_dir
                 or os.environ.get("JAX_COMPILATION_CACHE_DIR")
                 or _DEFAULT_DIR)
    pathlib.Path(cache_dir).mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    try:
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except AttributeError:      # option renamed across jax versions
        pass
    return cache_dir


class CompileWatcher:
    """Counts backend compiles and sums their duration via jax.monitoring.

    Listeners cannot be unregistered on this jax version, so one watcher
    is installed per process and windows are taken with :meth:`snapshot` /
    ``since``.  Durations come from the monitoring events; timestamps are
    recorded at event receipt so a window can be attributed to a wall-clock
    span (e.g. "compiles during the first round").
    """

    _installed: Optional["CompileWatcher"] = None

    def __init__(self):
        # (t_received, secs, is_backend_compile)
        self.events: List[Tuple[float, float, bool]] = []

        def _listen(event: str, secs: float, **kw):
            if event in _PIPELINE_EVENTS:
                self.events.append((time.perf_counter(), float(secs),
                                    event == _COMPILE_EVENT))

        jax.monitoring.register_event_duration_secs_listener(_listen)

    @classmethod
    def install(cls) -> "CompileWatcher":
        if cls._installed is None:
            cls._installed = cls()
        return cls._installed

    def snapshot(self) -> int:
        """Marker for a window start: the current event count."""
        return len(self.events)

    def since(self, mark: int, t_start: float | None = None,
              t_end: float | None = None) -> Tuple[int, float]:
        """(backend_compile_count, total_pipeline_secs) after ``mark``,
        optionally restricted to events received in [t_start, t_end]
        perf-counter time.  The count tracks executables built; the
        seconds include tracing + lowering + backend compile (the whole
        host stall a cold dispatch pays)."""
        window = self.events[mark:]
        if t_start is not None:
            window = [e for e in window if e[0] >= t_start]
        if t_end is not None:
            window = [e for e in window if e[0] <= t_end]
        return (sum(1 for e in window if e[2]),
                sum(e[1] for e in window))
