import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""SPerf hillclimb runner: apply one named optimization configuration to a
(arch, shape) pair, re-lower, re-analyse, and append the record (with the
hypothesis text) to reports/perf_iterations.json.

Usage:
  python -m repro.launch.hillclimb --arch granite-moe-1b-a400m \
      --shape train_4k --step moe_sg
  python -m repro.launch.hillclimb --list
"""

import argparse
import json
from typing import Any, Dict

#: name -> (cfg overrides, hypothesis text)
STEPS: Dict[str, Dict[str, Any]] = {
    "baseline": dict(
        overrides={},
        hypothesis="paper-faithful baseline (re-measurement)",
    ),
    "moe_sg": dict(
        overrides={"moe_stop_gradient_dispatch": True},
        hypothesis=(
            "the MoE dispatch/combine one-hots are integer-valued, so their "
            "cotangents are mathematically zero; stop_gradient removes the "
            "f32 (S,E,C) backward all-gathers (HLO showed 60 GiB of them) "
            "-> collective term should drop several-fold; FLOPs slightly "
            "down; forward numerics identical (verified bit-exact)"
        ),
    ),
    "pad_vocab": dict(
        overrides={"pad_vocab_multiple": 16},
        hypothesis=(
            "vocab not divisible by tp=16 leaves the LM head unsharded; "
            "every CE chunk all-reduces (B,cs,V) f32 partials (12.3 GiB on "
            "granite). Megatron-style padding shards the head -> those "
            "all-reduces become (B,cs) scalars"
        ),
    ),
    "moe_sg+pad": dict(
        overrides={"moe_stop_gradient_dispatch": True, "pad_vocab_multiple": 16},
        hypothesis="compose moe_sg and pad_vocab",
    ),
    "moe_sg+pad+group": dict(
        overrides={"moe_stop_gradient_dispatch": True, "pad_vocab_multiple": 16,
                   "moe_group": 1024},
        hypothesis=(
            "dispatch bytes scale with group size (S_g x E x C, C ~ S_g); "
            "1024-token groups cut the one-hot traffic ~4x -> memory term "
            "down on MoE train"
        ),
    ),
    "gqa": dict(
        overrides={"gqa_native": True},
        hypothesis=(
            "repeat_kv materializes H/KV-times larger K/V per layer "
            "(8x for qwen2/llama3); contracting the grouped layout reads "
            "K/V once -> memory term down on attention-heavy prefill"
        ),
    ),
    "gqa+chunk2k": dict(
        overrides={"gqa_native": True, "attn_chunk": 2048},
        hypothesis=(
            "larger q-chunks amortize K/V re-reads across chunks: HBM "
            "traffic for K/V scales with n_chunks; 2048-chunks halve it if "
            "score memory still fits"
        ),
    ),
    "gqa+ce1k": dict(
        overrides={"gqa_native": True, "ce_chunk": 1024},
        hypothesis="halve CE-chunk count: fewer head re-reads in fwd+bwd",
    ),
    "moe_group_512": dict(
        overrides={"moe_stop_gradient_dispatch": True, "pad_vocab_multiple": 16,
                   "moe_group": 512},
        hypothesis="push dispatch-group scaling further (512-token groups)",
    ),
}


def flash_whatif(arch: str, shape_name: str, report: str) -> Dict[str, Any]:
    """What-if analysis: replace the XLA attention path's HBM traffic with
    the fused Pallas flash kernel's (kernels/flash_attention.py, validated
    in interpret mode -- it cannot be *compiled* on this CPU container, so
    its effect on the roofline is derived by measuring the XLA attention
    component in isolation at production shape+sharding and substituting
    the kernel's q+k+v+o traffic)."""
    import dataclasses
    import functools
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, get_shape
    from repro.models.layers import attention, repeat_kv
    from .dryrun import _cost_dict, _with_depth
    from .mesh import HW, make_production_mesh
    from .sharding import make_plan, axis_size

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=False)
    plan = make_plan(mesh, cfg)
    chips = int(np.prod(mesh.devices.shape))
    qc = 256 if plan.huge and cfg.attn_chunk > 256 else cfg.attn_chunk

    B = shape.global_batch
    S = shape.seq_len + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q_s = jax.ShapeDtypeStruct((B, S, H, hd), jnp.bfloat16)
    kv_s = jax.ShapeDtypeStruct((B, S, KV, hd), jnp.bfloat16)

    def attn_fn(q, k, v):
        return attention(q, repeat_kv(k, H // KV), repeat_kv(v, H // KV),
                         causal=True, q_chunk=qc, unroll=True)

    bspec = P("data", None, "model" if H % 16 == 0 else None, None)
    kvspec = P("data", None, None, None)
    sh = lambda s: NamedSharding(mesh, s)
    lowered = jax.jit(
        attn_fn, in_shardings=(sh(bspec), sh(kvspec), sh(kvspec))
    ).lower(q_s, kv_s, kv_s)
    cc = lowered.compile()
    xla_bytes = _cost_dict(cc)["bytes accessed"]        # per device, 1 layer
    flash_bytes = (2 * B * S * H * hd + 2 * B * S * KV * hd) * 2 / chips

    # read the baseline record
    import json as _json
    base = None
    for path in ("reports/dryrun.json", report):
        if os.path.exists(path):
            for r in _json.load(open(path)):
                if (r["arch"], r["shape"], r.get("multi_pod")) == (arch, shape_name, False) \
                        and r["status"] == "ok" and not r.get("tag"):
                    base = r
    assert base, "run the baseline dry-run first"
    L = cfg.n_layers
    saved = max(xla_bytes - flash_bytes, 0.0) * L
    mem_new = base["roofline"]["memory_s"] - saved / HW.HBM_BW
    rec = dict(base)
    rec["tag"] = "flash_whatif"
    rec["hypothesis"] = (
        "the f32 score/prob matrices written to HBM per (q-chunk x layer) "
        "dominate prefill memory; the fused flash kernel keeps them in VMEM "
        "so per-layer attention traffic collapses to q+k+v+o"
    )
    rec["attention_component_bytes_per_layer"] = xla_bytes
    rec["flash_bytes_per_layer"] = flash_bytes
    rec["roofline"] = dict(base["roofline"])
    rec["roofline"]["memory_s"] = mem_new
    rec["roofline"]["bottleneck"] = max(
        ("compute", rec["roofline"]["compute_s"]),
        ("memory", mem_new),
        ("collective", rec["roofline"]["collective_s"]),
        key=lambda kv: kv[1])[0]
    print(f"[flash_whatif] {arch} x {shape_name}: attention component "
          f"{xla_bytes/2**30:.2f} GiB/layer -> flash {flash_bytes/2**30:.3f} "
          f"GiB/layer; memory term {base['roofline']['memory_s']*1e3:.0f}ms "
          f"-> {mem_new*1e3:.0f}ms (bottleneck {rec['roofline']['bottleneck']})")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--step", default=None)
    ap.add_argument("--overrides", default=None,
                    help="JSON dict of ArchConfig overrides (ad-hoc step)")
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--method", default="gradestc")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--report", default="reports/perf_iterations.json")
    args = ap.parse_args(argv)

    if args.list:
        for name, s in STEPS.items():
            print(f"{name:20s} {s['overrides']}")
        return 0

    from .dryrun import dryrun_pair

    if args.step == "flash_whatif":
        rec = flash_whatif(args.arch, args.shape, args.report)
        data = []
        if os.path.exists(args.report):
            with open(args.report) as f:
                data = json.load(f)
        data.append(rec)
        with open(args.report, "w") as f:
            json.dump(data, f, indent=1)
        return 0

    if args.overrides:
        step = dict(overrides=json.loads(args.overrides),
                    hypothesis=args.hypothesis or "(ad-hoc)")
        args.step = args.step or "adhoc"
    else:
        step = STEPS[args.step]
    rec = dryrun_pair(
        args.arch, args.shape, method=args.method,
        cfg_overrides=step["overrides"], tag=args.step,
    )
    rec["hypothesis"] = step["hypothesis"]

    data = []
    if os.path.exists(args.report):
        with open(args.report) as f:
            data = json.load(f)
    data.append(rec)
    os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
    with open(args.report, "w") as f:
        json.dump(data, f, indent=1)

    if rec["status"] == "ok" and "roofline" in rec:
        r = rec["roofline"]
        print(f"[{args.step}] {args.arch} x {args.shape}: "
              f"compute={r['compute_s']*1e3:.1f}ms mem={r['memory_s']*1e3:.1f}ms "
              f"coll={r['collective_s']*1e3:.1f}ms -> {r['bottleneck']} "
              f"(peak {rec['memory']['peak_bytes_tpu']/2**30:.2f}GiB)")
    else:
        print(f"[{args.step}] status={rec['status']}: {rec.get('error','')[:200]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
