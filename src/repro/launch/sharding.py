"""Divisibility-aware sharding policy for every arch family and input shape.

Concepts
--------
* ``tp_axes``   -- mesh axes carrying tensor/expert parallelism.  ("model",)
  for architectures whose per-replica footprint fits a 16-chip group;
  ("data", "model") (FSDP-style, 256-way) for the huge ones (dbrx-132b,
  qwen2-vl-72b, yi-34b) whose weights cannot replicate per client group.
* ``client_axes`` -- mesh axes enumerating FL clients in the train step
  (DESIGN.md Sec. 3).  Complement of tp_axes (plus "pod" when present).
* every rule shards a dimension only when its size is divisible by the mesh
  axis size -- otherwise the dimension stays replicated (GSPMD needs even
  partitions for inputs/outputs).

GradESTC state/specs: the segmented gradient matrix is oriented so that its
row axis ``l`` coincides with the parameter's tp-sharded dimension; the basis
``M (l, k)`` then shards on ``l`` and the whole codec is shard-local except a
small ``(k, m)`` psum and the payload gather over clients (DESIGN.md Sec. 5,
"TPU-native rethinking").
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import param_group_shapes
from repro.models.config import ArchConfig, InputShape

__all__ = [
    "MeshPlan", "make_plan", "param_specs", "batch_specs", "cache_specs",
    "named", "axis_size", "FLRoundSpecs",
]

#: per-replica bf16 bytes above which clients can no longer hold replicas on
#: a 16-chip group (4 copies live during an FL round: global, client, delta,
#: grads; budget ~12 GB of 16 GB HBM).
_HUGE_BYTES = 12 * 1024**3 / 4


class MeshPlan:
    """Resolved axis assignment for one (arch, mesh) pair."""

    def __init__(self, mesh: Mesh, cfg: ArchConfig):
        self.mesh = mesh
        self.cfg = cfg
        self.axes = tuple(mesh.axis_names)
        n_params = sum(
            int(np.prod(shape)) * stack
            for shape, stack in param_group_shapes(cfg).values()
        )
        self.param_bytes = 2 * n_params
        self.huge = self.param_bytes > _HUGE_BYTES * axis_size(mesh, "model")
        if self.huge:
            # 2-D weight sharding regime: within every layer matrix one dim
            # shards over "model" and a second over "data" (256-way), so
            # weights, grads, optimizer state and codec state all fit; the
            # batch also shards over "data" (weights are transiently
            # re-gathered as needed -- FSDP-like).  Clients = whole pods.
            self.tp_axes: Tuple[str, ...] = ("model",)
            self.second_axes: Tuple[str, ...] = ("data",)
            self.flat_tp_axes: Tuple[str, ...] = ("data", "model")
            self.client_axes: Tuple[str, ...] = ("pod",) if "pod" in self.axes else ()
            self.inner_batch_axes: Tuple[str, ...] = ("data",)
        else:
            self.tp_axes = ("model",)
            self.second_axes = ()
            self.flat_tp_axes = ("model",)
            self.client_axes = tuple(a for a in ("pod", "data") if a in self.axes)
            #: batch axes for per-client batches (train) -- axes not used by
            #: clients or tp
            self.inner_batch_axes = tuple(
                a for a in self.axes
                if a not in self.client_axes and a not in self.tp_axes
            )
        #: batch axes for serving (no client axis)
        self.serve_batch_axes = tuple(a for a in self.axes if a != "model")

    @property
    def n_clients(self) -> int:
        n = 1
        for a in self.client_axes:
            n *= axis_size(self.mesh, a)
        return max(n, 1)

    def tp_size(self) -> int:
        n = 1
        for a in self.tp_axes:
            n *= axis_size(self.mesh, a)
        return n

    # -- helpers -----------------------------------------------------------

    def shard_dim(self, size: int, axes: Tuple[str, ...]) -> Optional[Tuple[str, ...]]:
        """Return axes if ``size`` divides evenly over them, else None."""
        total = 1
        for a in axes:
            total *= axis_size(self.mesh, a)
        return axes if size % total == 0 and total > 1 else None


def axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def make_plan(mesh: Mesh, cfg: ArchConfig) -> MeshPlan:
    return MeshPlan(mesh, cfg)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------

def _matrix_spec(plan: MeshPlan, shape: Tuple[int, ...], prefer: int,
                 tp: Optional[Tuple[str, ...]] = None) -> P:
    """Spec for a per-layer matrix: shard dim ``prefer`` over ``tp`` axes
    when divisible, else try the other matrix dims, else replicate."""
    nd = len(shape)
    tp = plan.tp_axes if tp is None else tp
    order = [prefer] + [i for i in range(nd) if i != prefer]
    for dim in order:
        if plan.shard_dim(shape[dim], tp):
            spec = [None] * nd
            spec[dim] = tp if len(tp) > 1 else tp[0]
            return P(*spec)
    # fall back to model-only when the combined axes don't divide
    if len(tp) > 1:
        for dim in order:
            if plan.shard_dim(shape[dim], ("model",)):
                spec = [None] * nd
                spec[dim] = "model"
                return P(*spec)
    return P(*([None] * nd))


#: group-name fragment -> preferred shard dim index (within per-layer shape).
#: Column-parallel for input projections, row-parallel for output
#: projections (megatron pattern); expert axis for MoE banks; vocab for
#: embeddings.
_PREFER_RULES = (
    ("moe_wgate", 0), ("moe_win", 0), ("moe_wout", 0),       # (E, D, F): E
    ("router", 1),
    ("attn_wq", 1), ("attn_wk", 1), ("attn_wv", 1), ("attn_wo", 0),
    ("wq", 1), ("wk", 1), ("wv", 1), ("wo", 0),
    ("mlp_wgate", 1), ("mlp_win", 1), ("mlp_wout", 0),
    ("cm_wk", 1), ("cm_wv", 0), ("cm_wr", 1),
    ("tm_wr", 1), ("tm_wk", 1), ("tm_wv", 1), ("tm_wg", 1), ("tm_wo", 0),
    ("w_y", 1), ("w_x", 1), ("w_rg", 1), ("w_ig", 1), ("w_o", 0),
    ("embed", 0), ("head", 1), ("pos", 0),
)


def _prefer_for(name: str, shape: Tuple[int, ...]) -> int:
    for frag, dim in _PREFER_RULES:
        if frag in name:
            return min(dim, len(shape) - 1)
    return len(shape) - 1


_STACK_CONTAINERS = ("/layers/", "/rec/", "/attn/", "/enc/", "/dec/")


def _spec_tree(plan: MeshPlan, params: Any, path: str = "", role: str = "train") -> Any:
    if isinstance(params, dict):
        return {k: _spec_tree(plan, v, f"{path}/{k}", role) for k, v in params.items()}
    shape = tuple(params.shape)
    under_stack = any(seg in path for seg in _STACK_CONTAINERS)
    if len(shape) <= 1 or (under_stack and len(shape) == 2):
        # 1-D, or stacked per-layer vectors (L, D): replicate (tiny)
        return P(*([None] * len(shape)))
    if under_stack and len(shape) >= 3:
        per_layer = shape[1:]
        prefer = _prefer_for(path, per_layer)
        if role == "serve" and plan.huge and "moe_w" in path:
            # expert-parallel serving: experts over "data", ffn over "model"
            inner = [None] * len(per_layer)
            if plan.shard_dim(per_layer[0], ("data",)):
                inner[0] = "data"
            for i in sorted(range(1, len(per_layer)), key=lambda i: -per_layer[i]):
                if plan.shard_dim(per_layer[i], ("model",)):
                    inner[i] = "model"
                    break
            return P(None, *inner)
        # train (or small archs): within-layer "model" on the preferred dim
        # plus (huge regime, train only) "data" on the largest other
        # divisible dim -- 2-D sharding so weights/grads/codec state are
        # 256-way sharded.  Serving keeps weights model-only so activations
        # stay batch-sharded over "data" (the 2-D weight sharding would
        # force a full-batch activation gather -- measured 28 GiB attention
        # score buffers on yi-34b prefill).
        inner = list(_matrix_spec(plan, per_layer, prefer, tp=plan.tp_axes))
        if plan.second_axes and role == "train":
            cands = sorted(
                (i for i in range(len(per_layer)) if inner[i] is None),
                key=lambda i: -per_layer[i],
            )
            for i in cands:
                if plan.shard_dim(per_layer[i], plan.second_axes):
                    sa = plan.second_axes
                    inner[i] = sa if len(sa) > 1 else sa[0]
                    break
        return P(None, *inner)
    # unstacked tensors (embeddings, heads, positional tables)
    prefer = _prefer_for(path, shape)
    return _matrix_spec(plan, shape, prefer, tp=plan.flat_tp_axes)


def param_specs(plan: MeshPlan, params: Any, role: str = "train") -> Any:
    """PartitionSpec pytree matching ``params`` (no client axis)."""
    return _spec_tree(plan, params, role=role)


def client_stacked_specs(plan: MeshPlan, params: Any) -> Any:
    """Specs for per-client replicated params: leading client axis sharded
    over ``client_axes``."""
    base = param_specs(plan, params)
    cl = plan.client_axes
    cspec = cl if len(cl) > 1 else (cl[0] if cl else None)
    return jax.tree.map(
        lambda s: P(cspec, *s), base,
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------------------
# FL round specs (consumed by fl/engine.py's sharded round)
# --------------------------------------------------------------------------


class FLRoundSpecs:
    """Axis assignment for the sharded fused FL chunk (DESIGN.md Secs.
    10-11).

    Everything the single-host engine needs to run a K-round scan chunk
    under ``shard_map``: which mesh axes enumerate the selected clients
    and the chunk batch-block placement (via :func:`batch_specs`).  Model
    params, codec shared state, and the persistent per-client state store
    stay replicated (``P()``); only the *selected-client* axis shards.
    Per-round selection ids and padding masks are derived in-jit inside
    the chunk body, so they need no host-side placement.
    """

    def __init__(self, plan: MeshPlan):
        self.plan = plan
        self.mesh = plan.mesh
        cl = plan.client_axes
        if not cl:
            raise ValueError(
                f"mesh {plan.mesh.axis_names} has no client axes for FL "
                "(need 'data' and/or 'pod')")
        if plan.inner_batch_axes:
            # batch_specs places inner batch axes on dim 1, which in the FL
            # round block (C, steps, B, S) is local_steps -- meshes whose
            # non-model axes are not all client axes need a per-client
            # batch sharding rule that does not exist yet.
            raise ValueError(
                f"mesh {plan.mesh.axis_names}: non-client batch axes "
                f"{plan.inner_batch_axes} are not supported for the "
                "sharded FL round (use make_fl_mesh)")
        #: axis-name argument for collectives (psum / all_gather)
        self.client_axis_name = cl if len(cl) > 1 else cl[0]
        #: replicated spec (params, codec state stores, shared state)
        self.replicated = P()

    @property
    def n_shards(self) -> int:
        return self.plan.n_clients     # product of client-axis sizes

    def batch_chunk(self, batches) -> Dict[str, P]:
        """Specs for the (K, C_pad, steps, B, S) scan-chunk batch block:
        the leading scan-round axis is replicated (every shard walks the
        same K rounds), the client axis shards per :func:`batch_specs`."""
        per_round = batch_specs(
            self.plan, {k: v[0] for k, v in batches.items()},
            client_axis=True)
        return {k: P(None, *per_round[k]) for k in batches}

    def pad_clients(self, n_sel: int) -> int:
        """Selected-client axis padded up to a multiple of the shard count."""
        s = self.n_shards
        return -(-n_sel // s) * s

    # -- device placement --------------------------------------------------

    def put_batch_chunk(self, batches):
        """``device_put`` a host (K, C_pad, ...) chunk block under the
        chunk sharding."""
        specs = self.batch_chunk(batches)
        return {k: jax.device_put(v, named(self.mesh, specs[k]))
                for k, v in batches.items()}

    def put_replicated(self, tree):
        sh = named(self.mesh, P())
        return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


# --------------------------------------------------------------------------
# batch and cache specs
# --------------------------------------------------------------------------

def batch_specs(plan: MeshPlan, batch: Dict[str, Any], *, client_axis: bool) -> Dict[str, P]:
    """tokens/labels (B, S) or (C, B, S); modality stubs get matching specs."""
    out = {}
    for k, v in batch.items():
        nd = v.ndim
        if client_axis:
            cl = plan.client_axes
            cspec = cl if len(cl) > 1 else (cl[0] if cl else None)
            ib = plan.inner_batch_axes
            bspec = ib if len(ib) > 1 else (ib[0] if ib else None)
            out[k] = P(cspec, bspec, *([None] * (nd - 2)))
        else:
            sb = plan.serve_batch_axes
            B = v.shape[0]
            total = 1
            for a in sb:
                total *= axis_size(plan.mesh, a)
            if B % max(total, 1) == 0 and total > 1:
                out[k] = P(sb if len(sb) > 1 else sb[0], *([None] * (nd - 1)))
            else:
                out[k] = P(*([None] * nd))
    return out


def cache_specs(plan: MeshPlan, cache: Any, batch: int) -> Any:
    """KV/recurrent cache specs for serving.

    Batch shards over the serve batch axes when divisible; otherwise
    (long_500k, batch=1) the *sequence* axis shards there (flash-decoding
    over sequence shards).  Head/feature trailing dims shard over "model"
    when divisible.
    """
    mesh = plan.mesh
    sb = plan.serve_batch_axes
    sb_total = 1
    for a in sb:
        sb_total *= axis_size(mesh, a)
    sb_spec = sb if len(sb) > 1 else (sb[0] if sb else None)

    def leaf_spec(x) -> P:
        shape = tuple(x.shape)
        nd = len(shape)
        if nd == 0:
            return P()
        spec = [None] * nd
        # identify axes: (L, B, S, KV, hd) / (L, B, S, H, hd) / (L, B, D) /
        # (L, B, cw-1, R) / (L, B, H, hd, hd)
        if nd >= 2 and shape[1] == batch:
            if batch % sb_total == 0 and sb_total > 1:
                spec[1] = sb_spec
            elif nd >= 3 and shape[2] % sb_total == 0 and sb_total > 1:
                spec[2] = sb_spec          # shard sequence instead
        # trailing feature dims over model
        for dim in range(nd - 1, 1, -1):
            if spec[dim] is None and plan.shard_dim(shape[dim], ("model",)):
                spec[dim] = "model"
                break
        return P(*spec)

    return jax.tree.map(
        lambda x: leaf_spec(x),
        cache,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, (dict, tuple, list)),
    )
