"""SPMD step functions lowered by the dry-run and the production drivers.

Three kinds, per input shape:

  * train_4k            -> ``fl_round_step``: one FedAvg round -- broadcast
    global params to per-client replicas, ``local_steps`` SGD steps per
    client on its batch shard, GradESTC-compress the deltas, aggregate the
    *compressed payloads* across clients, reconstruct, apply (server lr).
    The baseline variant aggregates dense deltas with a mean (all-reduce) --
    exactly the FedAvg reference the paper compares against.

  * prefill_32k         -> ``prefill_step``: full forward, returns logits of
    the last position + populated KV cache (abstract in the dry-run).

  * decode_32k/long_500k-> ``decode_step``: one token against the cache.

The GradESTC aggregation is written with ``shard_map`` around the payload
gather + local reconstruction so that the collective schedule is pinned:
an all-gather of (k x m coefficients + d x l/TP basis shards) over the
client axes, then a shard-local einsum -- never a full-gradient all-reduce
(DESIGN.md Sec. 3 "Uplink == the cross-client collective").
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import gradestc as ge
from repro.core.policy import CompressionPolicy, LayerPlan, make_policy
from repro.models import loss_fn, model, param_group_shapes
from repro.models.config import ArchConfig, InputShape

from .sharding import MeshPlan, axis_size, batch_specs, cache_specs, client_stacked_specs, param_specs

__all__ = [
    "GEState", "make_ge_state", "ge_state_specs",
    "make_fl_round_step", "make_serve_steps", "train_input_specs",
    "serve_input_specs", "compression_policy_for",
]


# --------------------------------------------------------------------------
# GradESTC distributed state
# --------------------------------------------------------------------------

class GEState(NamedTuple):
    """Per-(client, group) compressor state for the SPMD round step.

    M:    {group: (C, L, l, k)}   client axis sharded over client_axes,
                                  l sharded over tp axes when divisible.
    keys: {group: (C, L, 2)}      per-compressor PRNG keys.
    """

    M: Dict[str, jnp.ndarray]
    keys: Dict[str, jnp.ndarray]


def compression_policy_for(cfg: ArchConfig, plan: MeshPlan) -> CompressionPolicy:
    """Paper policy with the TPU alignment rule: the segment length l is the
    parameter's tp-sharded dimension (DESIGN.md Sec. 5) -- falling back to
    the sqrt rule when a group is unsharded."""
    groups = param_group_shapes(cfg)
    overrides = {}
    specs = None  # resolved lazily per group below
    for name, (shape, stack) in groups.items():
        if len(shape) < 2:
            continue
        n = int(np.prod(shape))
        if n < 65536:
            continue
        # orient l along the dim this framework shards for that group
        from .sharding import _matrix_spec, _prefer_for  # local import
        prefer = _prefer_for(name, shape)
        spec = _matrix_spec(plan, shape, prefer)
        sharded_dim = next(
            (i for i, s in enumerate(spec) if s is not None), None
        )
        if sharded_dim is None:
            continue  # unsharded group: keep the default sqrt rule
        l = int(shape[sharded_dim])
        if len(shape) > 2:
            # fold extra dims into m (e.g. MoE (E, D, F) with E sharded:
            # l = E is degenerate -- use the largest remaining dim instead)
            if l < 256:
                rest = [s for i, s in enumerate(shape) if i != sharded_dim]
                l = int(max(rest))
        m = n // l
        k = max(4, min(l // 8, m // 4, 64))
        k = 1 << (k.bit_length() - 1) if k & (k - 1) else k
        overrides[name] = (k, l)
    return make_policy(groups, overrides=overrides)


def make_ge_state(cfg: ArchConfig, policy: CompressionPolicy, n_clients: int,
                  seed: int = 0, dtype=jnp.float32) -> GEState:
    M, keys = {}, {}
    base = jax.random.PRNGKey(seed)
    for name, plan in policy.plans.items():
        if not plan.compress:
            continue
        M[name] = jnp.zeros((n_clients, plan.stack, plan.l, plan.k), dtype)
        keys[name] = jax.random.split(
            jax.random.fold_in(base, hash(name) % (2**31)),
            n_clients * plan.stack,
        ).reshape(n_clients, plan.stack, 2)
    return GEState(M=M, keys=keys)


def ge_state_specs(plan: MeshPlan, policy: CompressionPolicy) -> Any:
    cl = plan.client_axes
    cspec = cl if len(cl) > 1 else (cl[0] if cl else None)
    tp = plan.tp_axes
    M_specs, key_specs = {}, {}
    for name, lp in policy.plans.items():
        if not lp.compress:
            continue
        lspec = tp if len(tp) > 1 else tp[0]
        if lp.l % max(plan.tp_size(), 1) != 0:
            lspec = None
        M_specs[name] = P(cspec, None, lspec, None)
        key_specs[name] = P(cspec, None, None)
    return GEState(M=M_specs, keys=key_specs)


# --------------------------------------------------------------------------
# group <-> matrices plumbing (stacked, on-device)
# --------------------------------------------------------------------------

def _group_leaf(params: Any, path: str) -> jnp.ndarray:
    node = params
    for part in path.split("/"):
        node = node[part]
    return node


def _set_leaf(params: Any, path: str, val: jnp.ndarray) -> Any:
    parts = path.split("/")

    def rec(node, i):
        node = dict(node)
        if i == len(parts) - 1:
            node[parts[i]] = val
        else:
            node[parts[i]] = rec(node[parts[i]], i + 1)
        return node

    return rec(params, 0)


def _delta_to_G(delta: jnp.ndarray, lp: LayerPlan) -> jnp.ndarray:
    """(C?, L, *shape) -> (C?, L, l, m) oriented so rows are the l axis.

    The paper reshapes the WHDC-flattened vector into length-l column
    segments; with l chosen as one tensor dimension this is a transpose-
    reshape, shard-local when l is the tp-sharded dim."""
    lead = delta.shape[: delta.ndim - len(lp.shape)]
    shape = lp.shape
    # find the axis whose size == l (prefer exact match)
    ax = next((i for i, s in enumerate(shape) if s == lp.l), None)
    if ax is None:
        flat = delta.reshape(*lead, lp.m, lp.l)
        return jnp.swapaxes(flat, -1, -2)
    perm_tail = (ax,) + tuple(i for i in range(len(shape)) if i != ax)
    perm = tuple(range(len(lead))) + tuple(len(lead) + i for i in perm_tail)
    moved = jnp.transpose(delta, perm)
    return moved.reshape(*lead, lp.l, lp.m)


def _G_to_delta(G: jnp.ndarray, lp: LayerPlan, like_shape) -> jnp.ndarray:
    lead = G.shape[:-2]
    shape = lp.shape
    ax = next((i for i, s in enumerate(shape) if s == lp.l), None)
    if ax is None:
        flat = jnp.swapaxes(G, -1, -2).reshape(*lead, lp.n)
        return flat.reshape(like_shape)
    rest = tuple(s for i, s in enumerate(shape) if i != ax)
    moved = G.reshape(*lead, lp.l, *rest)
    inv = list(range(len(lead)))
    tail_perm = [0] * len(shape)
    tail_src = (ax,) + tuple(i for i in range(len(shape)) if i != ax)
    for pos, src in enumerate(tail_src):
        tail_perm[src] = len(lead) + pos
    return jnp.transpose(moved, tuple(inv) + tuple(tail_perm)).reshape(like_shape)


# --------------------------------------------------------------------------
# FL round step
# --------------------------------------------------------------------------

def make_fl_round_step(
    cfg: ArchConfig,
    mesh: Mesh,
    plan: MeshPlan,
    policy: CompressionPolicy,
    *,
    method: str = "gradestc",        # "gradestc" | "fedavg" | "fedpaq"
    local_steps: int = 1,
    grad_accum: int = 1,
    lr: float = 0.01,
    server_lr: float = 1.0,
    d_static: int = 16,
) -> Callable:
    """Build the jittable FL round function.

    signature: (global_params, ge_state, batches) ->
               (new_params, new_ge_state, metrics)
    batches: {tokens/labels: (C, B_c, S), ...}

    ``grad_accum`` splits each client batch into microbatches scanned with
    f32 gradient accumulation -- bounds the live activation-checkpoint
    memory to one microbatch (required for the huge FSDP-regime archs).
    """
    C = plan.n_clients
    group_paths = [p for p in policy.plans]
    comp_paths = [p for p, lp in policy.plans.items() if lp.compress]
    cl_axes = plan.client_axes

    def make_local_train(pin_grads):
        """pin_grads: optional fn pinning a grad pytree to the parameter
        shardings -- used in the FSDP (C == 1) regime where the f32
        accumulation carry would otherwise replicate over the data axis."""

        def client_grad(p, batch_c):
            if grad_accum == 1:
                g = jax.grad(lambda pp: loss_fn(cfg, pp, batch_c))(p)
                return pin_grads(g) if pin_grads else g
            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]),
                batch_c,
            )

            def acc_step(g_acc, mb):
                g = jax.grad(lambda pp: loss_fn(cfg, pp, mb))(p)
                if pin_grads:
                    g = pin_grads(g)
                out = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / grad_accum, g_acc, g
                )
                return (pin_grads(out) if pin_grads else out), None

            g0 = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), p)
            if pin_grads:
                g0 = pin_grads(g0)
            # cost-mode lowerings (cfg.attn_unroll) must unroll this scan
            # too, or cost_analysis counts a single microbatch (discovered
            # via a spurious 8x "win" -- EXPERIMENTS.md SPerf, dbrx iter 4)
            g_sum, _ = jax.lax.scan(acc_step, g0, mbs,
                                    unroll=grad_accum if cfg.attn_unroll else 1)
            return g_sum

        def local_train(params_c, batch_c):
            def one_step(p, _):
                g = client_grad(p, batch_c)
                return jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32) - lr * b.astype(jnp.float32)).astype(a.dtype),
                    p, g,
                ), None
            out, _ = jax.lax.scan(one_step, params_c, None, length=local_steps)
            return out

        return local_train

    def compress_group(Ms, keys, G, k: int, d: int):
        """vmapped over (C, L): returns new M, keys, payload pieces."""
        def one(Mi, key, Gi):
            st = ge.CompressorState(M=Mi, key=key, initialized=jnp.ones((), jnp.bool_))
            st2, payload, stats = ge.compress_update(st, Gi, k=k, d=d)
            return st2.M, st2.key, payload.coeffs, payload.new_vectors, payload.replaced_mask, stats.d_r
        f = jax.vmap(jax.vmap(one))
        return f(Ms, keys, G)

    def fl_round(global_params, ge_state: GEState, batches):
        # sharding pins at every stage boundary: without them GSPMD loses
        # the tensor-parallel sharding across the client-mean / loop-carry
        # boundaries and falls back to full per-device replication
        # (empirically 4x temp memory and 3x all-reduce bytes on
        # gemma3-1b/train_4k -- see EXPERIMENTS.md SPerf).
        from .sharding import client_stacked_specs, param_specs  # cycle-free
        p_specs = param_specs(plan, global_params)
        cs_specs = client_stacked_specs(plan, global_params)
        has_shape = lambda x: hasattr(x, "shape") and not isinstance(x, dict)

        def pin(tree, specs):
            return jax.tree.map(
                lambda a, s: jax.lax.with_sharding_constraint(
                    a, jax.sharding.NamedSharding(mesh, s)),
                tree, specs, is_leaf=has_shape,
            )

        if C == 1:
            # FSDP regime (huge archs): no client vmap; run the single
            # client unbatched so sharding pins apply at parameter rank.
            local_train = make_local_train(lambda g: pin(g, p_specs))
            batch_one = jax.tree.map(lambda x: x[0], batches)
            cp_one = local_train(pin(global_params, p_specs), batch_one)
            cp_one = pin(cp_one, p_specs)
            client_params = jax.tree.map(lambda p: p[None], cp_one)
        else:
            local_train = make_local_train(None)
            # 1. broadcast global -> per-client replicas
            client_params = jax.tree.map(
                lambda p: jnp.broadcast_to(p[None], (C,) + p.shape), global_params
            )
            client_params = pin(client_params, cs_specs)
            # 2. local training, vmapped over the client axis
            client_params = jax.vmap(local_train)(client_params, batches)
            client_params = pin(client_params, cs_specs)
        # 3. per-group deltas (C, L, ...), f32 for the codec
        metrics = {}
        new_M = dict(ge_state.M)
        new_keys = dict(ge_state.keys)
        recon_deltas = {}
        for path in group_paths:
            lp = policy.plans[path]
            g_new = _group_leaf(client_params, path)
            g_old = _group_leaf(global_params, path)
            delta = (g_new.astype(jnp.float32) - g_old.astype(jnp.float32)[None])
            if method == "gradestc" and lp.compress:
                G = _delta_to_G(delta.reshape((C, lp.stack) + lp.shape), lp)
                M2, k2, A, newvec, repl, d_r = compress_group(
                    ge_state.M[path], ge_state.keys[path], G, lp.k, d_static
                )
                new_M[path], new_keys[path] = M2, k2

                # -- aggregation: gather compressed payloads over clients,
                #    reconstruct shard-locally, average.  Ghat_c = M_c A_c.
                Ghat_avg = jnp.einsum("cxlk,cxkm->xlm", M2, A) / C
                recon = _G_to_delta(Ghat_avg, lp, (lp.stack,) + lp.shape)
                recon_deltas[path] = recon.reshape(g_old.shape)
                metrics[f"d_r/{path}"] = jnp.mean(d_r.astype(jnp.float32))
            else:
                recon_deltas[path] = jnp.mean(delta, axis=0).reshape(g_old.shape)
        # 4. server update (pinned back to the parameter shardings)
        new_params = global_params
        for path in group_paths:
            old = _group_leaf(global_params, path)
            spec = _group_leaf(p_specs, path)
            rec = jax.lax.with_sharding_constraint(
                recon_deltas[path], jax.sharding.NamedSharding(mesh, spec))
            upd = (old.astype(jnp.float32) + server_lr * rec).astype(old.dtype)
            new_params = _set_leaf(new_params, path, upd)
        metrics["loss_proxy"] = jnp.asarray(0.0)
        return new_params, GEState(M=new_M, keys=new_keys), metrics

    return fl_round


# --------------------------------------------------------------------------
# serve steps
# --------------------------------------------------------------------------

def make_serve_steps(cfg: ArchConfig):
    def prefill_step(params, batch):
        # hidden-then-head: only the last position's logits are formed
        # (materializing (B, S, V) at 32k x 262k vocab would be absurd).
        hidden, head = model.forward_hidden(cfg, params, batch)
        return (hidden[:, -1, :] @ head).astype(jnp.float32)

    def decode(params, cache, batch):
        return model.decode_step(cfg, params, cache, batch["tokens"])

    return prefill_step, decode


# --------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct) per shape
# --------------------------------------------------------------------------

def train_input_specs(cfg: ArchConfig, shape: InputShape, plan: MeshPlan):
    """{name: ShapeDtypeStruct} for one FL-round step's batches."""
    C = plan.n_clients
    B = shape.global_batch // C
    S = shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((C, B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((C, B, S), jnp.int32),
    }
    if cfg.family == "encdec":
        specs["audio_frames"] = jax.ShapeDtypeStruct(
            (C, B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm" and cfg.vision_tokens:
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (C, B, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


def serve_input_specs(cfg: ArchConfig, shape: InputShape, *, decode: bool):
    B, S = shape.global_batch, shape.seq_len
    if decode:
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "encdec":
        specs["audio_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm" and cfg.vision_tokens:
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs
