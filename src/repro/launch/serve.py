"""Batched serving driver: prefill a batch of prompts, then decode tokens.

Runs a reduced architecture on local devices (CPU here); the same
prefill/decode step functions are what the dry-run lowers at production
shapes.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --batch 4 --steps 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None) -> int:
    from repro.configs import get_config
    from repro.models import model

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(cfg, key)
    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab)

    decode = jax.jit(lambda p, c, t: model.decode_step(cfg, p, c, t))

    # prefill by stepping the decode path over the prompt (cache-exact)
    extra = {}
    if cfg.family == "encdec":
        from repro.models import encdec
        frames = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        enc_out = encdec.encode_audio(cfg, params, frames)
        cache = model.init_cache(cfg, B, args.max_len, enc_out=enc_out, params=params)
    else:
        cache = model.init_cache(cfg, B, args.max_len)

    t0 = time.time()
    logits = None
    for t in range(P):
        logits, cache = decode(params, cache, prompts[:, t:t+1])
    prefill_s = time.time() - t0

    out_tokens = []
    t0 = time.time()
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    for t in range(args.steps):
        out_tokens.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1, :] / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    decode_s = time.time() - t0

    gen = np.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name}  batch={B}")
    print(f"prefill: {P} tokens in {prefill_s:.2f}s "
          f"({B*P/max(prefill_s,1e-9):.1f} tok/s)")
    print(f"decode : {args.steps} steps in {decode_s:.2f}s "
          f"({B*args.steps/max(decode_s,1e-9):.1f} tok/s)")
    print("generated token ids (first sequence):", gen[0][:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
