"""Host environment tuning for benchmark and training entry points.

XLA reads most host knobs exactly once, at first ``import jax`` -- so this
module must stay importable without touching jax (``repro.launch`` exposes
its submodules lazily for the same reason), and ``configure_host()`` must be
called before the first jax import in the process.

Knobs (defaults only -- anything the user already exported wins):

  TF_CPP_MIN_LOG_LEVEL=4
      silence TF/XLA C++ banner noise that otherwise drowns bench output.
  TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
      with tcmalloc preloaded, suppress per-allocation warnings for the
      multi-GB host buffers the client-batch assembler reuses.
  XLA_FLAGS --xla_force_host_platform_device_count=N
      only when ``host_device_count`` is passed; merged into existing
      XLA_FLAGS, never overriding a count the user already forced.

tcmalloc itself cannot be enabled here: LD_PRELOAD is read by the dynamic
loader at process start.  ``configure_host`` detects whether it is active
(via /proc/self/maps) and reports the run.sh-style preload line to use when
it is not (see SNIPPETS.md / HomebrewNLP-Jax).
"""

from __future__ import annotations

import os
import sys
import warnings

_TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
)

_DEFAULTS = {
    "TF_CPP_MIN_LOG_LEVEL": "4",
    "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
}


def tcmalloc_active() -> bool:
    """True when a tcmalloc variant is linked into this process."""
    try:
        with open("/proc/self/maps") as f:
            return "tcmalloc" in f.read()
    except OSError:  # non-Linux: undetectable, assume not
        return False


def tcmalloc_hint() -> str | None:
    """The LD_PRELOAD line to get tcmalloc, or None if unavailable/active."""
    if tcmalloc_active():
        return None
    for path in _TCMALLOC_PATHS:
        if os.path.exists(path):
            return f"LD_PRELOAD={path}"
    return None


def merge_xla_flag(flags: str, flag: str, value: str, *,
                   force: bool = False) -> str:
    """Append ``flag=value`` to an XLA_FLAGS string.

    An already-present flag wins unless ``force`` -- the device-sweep
    benches must pin their per-child count even when the parent shell
    exported one.
    """
    if flag in flags:
        if not force:
            return flags
        kept = [t for t in flags.split() if not t.startswith(flag)]
        flags = " ".join(kept)
    return f"{flags} {flag}={value}".strip()


def configure_host(
    host_device_count: int | None = None, *, env: dict | None = None,
    verbose: bool = False,
) -> dict:
    """Apply default host tuning; returns {knob: value} for what was set.

    Pass ``env`` to tune a child-process environment dict (the device-sweep
    benches fork one child per device count) instead of ``os.environ``.
    Mutating ``os.environ`` after jax initialized is too late for XLA_FLAGS,
    so that combination warns and skips the flag merge.
    """
    target = os.environ if env is None else env
    applied = {}
    for k, v in _DEFAULTS.items():
        if k not in target:
            target[k] = v
            applied[k] = v
    if host_device_count is not None:
        if env is None and "jax" in sys.modules:
            warnings.warn(
                "configure_host(host_device_count=...) called after jax was "
                "imported: XLA_FLAGS is already frozen, flag not applied",
                stacklevel=2)
        else:
            flags = merge_xla_flag(
                target.get("XLA_FLAGS", ""),
                "--xla_force_host_platform_device_count",
                str(host_device_count), force=env is not None)
            if flags != target.get("XLA_FLAGS", ""):
                target["XLA_FLAGS"] = flags
                applied["XLA_FLAGS"] = flags
    hint = tcmalloc_hint()
    if verbose:
        for k, v in applied.items():
            print(f"[env] {k}={v}", file=sys.stderr)
        if hint:
            print(f"[env] tcmalloc not preloaded; for faster host malloc: "
                  f"{hint} (see DESIGN.md)", file=sys.stderr)
    return applied
