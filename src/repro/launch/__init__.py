"""repro.launch -- production mesh, sharding policy, dry-run, drivers.

NOTE: importing ``repro.launch.dryrun`` sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` and must happen
before any other jax initialization; never import it from library code.
The other modules are safe to import anywhere.
"""

from .mesh import HW, make_local_mesh, make_production_mesh
from .sharding import MeshPlan, make_plan

__all__ = ["HW", "make_local_mesh", "make_production_mesh", "MeshPlan", "make_plan"]
