"""repro.launch -- production mesh, sharding policy, dry-run, drivers.

Submodules are exposed lazily (PEP 562): ``repro.launch.env`` must be
importable -- and ``configure_host()`` callable -- *before* the first jax
import in the process (XLA reads XLA_FLAGS once, at jax init), so this
package must not import jax eagerly.

NOTE: importing ``repro.launch.dryrun`` sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` and must happen
before any other jax initialization; never import it from library code.
The other modules are safe to import anywhere.
"""

_LAZY = {
    "HW": ("mesh", "HW"),
    "make_local_mesh": ("mesh", "make_local_mesh"),
    "make_production_mesh": ("mesh", "make_production_mesh"),
    "MeshPlan": ("sharding", "MeshPlan"),
    "make_plan": ("sharding", "make_plan"),
}

__all__ = list(_LAZY)


def __getattr__(name):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    mod = importlib.import_module(f".{mod_name}", __name__)
    return getattr(mod, attr)
