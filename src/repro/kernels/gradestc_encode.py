"""Pallas TPU kernel: fused GradESTC projection  A = M^T G,  E = G - M A.

Why a kernel (DESIGN.md Sec. 3): this is the per-round compression hot spot.
Done naively it is two GEMMs with G (the large operand, l*m elements)
streamed from HBM twice -- the op is HBM-bandwidth-bound since k << l.  The
fusion streams each (l, bm) column block of G HBM->VMEM exactly once,
computes the (k, bm) coefficient block on the MXU, immediately forms the
residual block and writes both outputs.  HBM traffic drops from
  2*l*m (read) + l*m + k*m (write)   to   l*m (read) + l*m + k*m (write),
i.e. ~1.5x less for k << l -- directly attacking the roofline memory term.

Tiling
------
grid = (m // bm,).  Per grid step the VMEM working set is
    M (l, k)  +  G block (l, bm)  +  E block (l, bm)  +  A block (k, bm)
``ops.choose_block_m`` picks bm so this fits the v5e VMEM budget (~16 MB near
128-multiples for MXU alignment).  The basis M is small (k <= 128) and is
re-fetched per step from its BlockSpec (index_map pins it to block (0, 0), so
on TPU it stays VMEM-resident across the sweep).

Accumulation is f32 (``preferred_element_type``) regardless of input dtype.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = ["encode_pallas", "encode_quant_pallas"]


def _encode_kernel(m_ref, g_ref, a_ref, e_ref):
    """One (l, bm) column block: a = m^T g ; e = g - m a."""
    M = m_ref[...]                                  # (l, k)
    G = g_ref[...]                                  # (l, bm)
    A = jax.lax.dot_general(
        M, G, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                               # (k, bm) on the MXU
    Ghat = jax.lax.dot_general(
        M.astype(jnp.float32), A, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                               # (l, bm)
    a_ref[...] = A.astype(a_ref.dtype)
    e_ref[...] = (G.astype(jnp.float32) - Ghat).astype(e_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def encode_pallas(
    M: jnp.ndarray,
    G: jnp.ndarray,
    *,
    block_m: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused A = M^T G, E = G - M A.

    Args:
      M: (l, k) basis.  G: (l, m) reshaped gradient, m % block_m == 0.
      block_m: column tile width (multiple of 128 for MXU alignment).
      interpret: run the kernel body in Python on CPU (validation mode).

    Returns: (A (k, m), E (l, m)) in G.dtype.
    """
    l, k = M.shape
    l2, m = G.shape
    assert l == l2, f"M rows {l} != G rows {l2}"
    assert m % block_m == 0, f"m={m} not divisible by block_m={block_m}"

    grid = (m // block_m,)
    return pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((l, k), lambda j: (0, 0)),          # M pinned
            pl.BlockSpec((l, block_m), lambda j: (0, j)),    # G column block
        ],
        out_specs=[
            pl.BlockSpec((k, block_m), lambda j: (0, j)),    # A
            pl.BlockSpec((l, block_m), lambda j: (0, j)),    # E
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, m), G.dtype),
            jax.ShapeDtypeStruct((l, m), G.dtype),
        ],
        interpret=interpret,
    )(M, G)


# ---------------------------------------------------------------------------
# fused projection + int8 coefficient wire (SVDFed steady-state uplink)
# ---------------------------------------------------------------------------

def _encode_quant_kernel(m_ref, g_ref, c_ref, s_ref, e_ref):
    """One (l, 512) column block: project, int8-quantize, residual vs ship."""
    M = m_ref[...]                                  # (l, k)
    G = g_ref[...]                                  # (l, 512)
    A = jax.lax.dot_general(
        M, G, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                               # (k, 512)
    scale = jnp.maximum(jnp.max(jnp.abs(A), axis=1, keepdims=True), 1e-12)
    codes = jnp.clip(jnp.round(A / scale * 127.0), -127.0, 127.0)
    ship = codes * (scale * ref.INV127)
    Ghat = jax.lax.dot_general(
        M.astype(jnp.float32), ship, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    c_ref[...] = codes.astype(jnp.int8)
    s_ref[...] = scale
    e_ref[...] = (G.astype(jnp.float32) - Ghat).astype(e_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def encode_quant_pallas(
    M: jnp.ndarray, G: jnp.ndarray, *, interpret: bool = False
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused A = M^T G -> int8 wire -> E = G - M ship, one G pass.

    The column tile is pinned at 512 (the wire's scale-block width) so each
    grid step owns exactly one scale column; ``ops.encode_quant`` checks the
    VMEM budget fits this tile and falls back to the oracle otherwise.

    Args: M (l, k), G (l, m) with m % 512 == 0.
    Returns (codes int8 (k, m), scales f32 (k, m/512), E (l, m) G.dtype) --
    the residual is against the *shipped* (dequantized) coefficients, the
    error the server actually cannot see.
    """
    l, k = M.shape
    l2, m = G.shape
    assert l == l2 and m % 512 == 0
    grid = (m // 512,)
    return pl.pallas_call(
        _encode_quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((l, k), lambda j: (0, 0)),          # M pinned
            pl.BlockSpec((l, 512), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((k, 512), lambda j: (0, j)),
            pl.BlockSpec((k, 1), lambda j: (0, j)),
            pl.BlockSpec((l, 512), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, m), jnp.int8),
            jax.ShapeDtypeStruct((k, m // 512), jnp.float32),
            jax.ShapeDtypeStruct((l, m), G.dtype),
        ],
        interpret=interpret,
    )(M, G)
