"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics the kernels must match (asserted across a
shape/dtype sweep in tests/test_kernels.py and tests/test_wire.py, kernels
run with interpret=True on CPU).

Wire-format conventions (DESIGN.md "Wire-format layer")
-------------------------------------------------------
All packed wire words are ``uint32`` in the canonical layout

    code i  ->  word i // cpw,  shift (i % cpw) * bits,   cpw = 32 // bits

i.e. little-endian within a word, codes in flat row-major order.  Quantized
codes are packed *biased*: a signed code in ``[-levels, levels]`` ships as
``code + levels`` (max ``2*levels = 2**bits - 2``, which fits ``bits``).
Scales ride next to the words as f32 -- one per ``WIRE_BLOCK`` codes for the
block quantizer, one per (row, 512-column block) for coefficient matrices,
one global mean-|g| for the sign wire.

The sign wire's scale is a **two-stage** reduction: |g| is padded to
``(rows, WIRE_BLOCK)``, summed per row, then across rows.  The Pallas kernel
produces the per-row partials and the dispatcher sums them, so oracle and
kernel see the identical float reduction tree (bit-exactness is asserted,
not approximated).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .quant import quant_levels

__all__ = [
    "WIRE_BLOCK",
    "encode_ref", "decode_ref", "block_quant_ref", "block_dequant_ref",
    "pack_codes_ref", "unpack_codes_ref",
    "sign_pack_ref", "sign_unpack_ref", "mean_abs_ref",
    "quant_pack_ref", "unpack_dequant_ref",
    "coeff_quant_ref", "coeff_dequant_ref",
    "bf16_pack_ref", "bf16_unpack_ref",
    "encode_quant_ref",
]

#: codes per scale row for every packed wire format (also the lane width the
#: Pallas kernels tile against -- 4 * the f32 min-tile lane count).
WIRE_BLOCK = 512

# Single-rounded f32 reciprocal of the int8 range.  The coefficient wire's
# dequant is *defined* as codes * (scale * INV127): multiplying by the same
# pre-rounded constant on every path (oracle, wire kernels, fused GEMMs)
# keeps them bit-identical regardless of whether XLA strength-reduces a
# division in one fusion context but not another.
INV127 = float(np.float32(1.0) / np.float32(127.0))


def encode_ref(M: jnp.ndarray, G: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused GradESTC projection: A = M^T G, E = G - M A.

    M: (l, k) orthonormal basis.  G: (l, m).  Returns (A (k, m), E (l, m)).
    Accumulation in f32 regardless of input dtype (MXU-accurate semantics).
    """
    M32 = M.astype(jnp.float32)
    G32 = G.astype(jnp.float32)
    A = M32.T @ G32
    E = G32 - M32 @ A
    return A.astype(G.dtype), E.astype(G.dtype)


def decode_ref(M: jnp.ndarray, A: jnp.ndarray) -> jnp.ndarray:
    """GradESTC reconstruction: Ghat = M A.  M: (l, k), A: (k, m)."""
    out = M.astype(jnp.float32) @ A.astype(jnp.float32)
    return out.astype(M.dtype)


def block_quant_ref(
    g: jnp.ndarray, uniforms: jnp.ndarray, block: int, bits: int = 8
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-wise stochastic uniform quantization (TPU-native FedPAQ variant).

    g: (n,) with n % block == 0.  uniforms: (n,) iid U[0,1) used for the
    stochastic rounding.  Each length-``block`` slice gets its own max-abs
    scale (better accuracy than one global scale, and each tile's scale is
    computable inside one VMEM-resident block -- the TPU adaptation).

    Returns (codes int8 in [-(2^(bits-1)-1), 2^(bits-1)-1], scales (n/block,)).
    """
    levels = quant_levels(bits)        # symmetric signed code book
    gb = g.reshape(-1, block).astype(jnp.float32)
    ub = uniforms.reshape(-1, block).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gb), axis=1, keepdims=True), 1e-12)
    x = gb / scale * levels            # [-levels, levels]
    lo = jnp.floor(x)
    codes = lo + (ub < (x - lo)).astype(jnp.float32)
    codes = jnp.clip(codes, -levels, levels)
    return codes.astype(jnp.int8).reshape(g.shape), scale[:, 0]


def block_dequant_ref(
    codes: jnp.ndarray, scales: jnp.ndarray, block: int, bits: int = 8
) -> jnp.ndarray:
    levels = quant_levels(bits)
    # Single-rounded f32 reciprocal, multiplied -- not divided -- so the
    # oracle and the Pallas kernels share one bit-exact dequant definition
    # (XLA strength-reduces /const to *recip inside kernels; doing it
    # explicitly on both sides removes the 1-ulp split).
    inv = float(np.float32(1.0) / np.float32(levels))
    cb = codes.reshape(-1, block).astype(jnp.float32)
    return (cb * (scales[:, None] * inv)).reshape(codes.shape)


# ---------------------------------------------------------------------------
# bit-packing primitives (canonical layout, see module docstring)
# ---------------------------------------------------------------------------

def pack_codes_ref(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack unsigned codes in [0, 2**bits - 1] into dense uint32 wire words.

    codes: (..., n) any integer dtype.  Returns (..., ceil(n / cpw)) uint32.
    The tail word is zero-padded (pad codes are 0).
    """
    assert 1 <= bits <= 16
    cpw = 32 // bits
    n = codes.shape[-1]
    pad = (-n) % cpw
    c = codes.astype(jnp.uint32)
    if pad:
        c = jnp.pad(c, [(0, 0)] * (c.ndim - 1) + [(0, pad)])
    c = c.reshape(c.shape[:-1] + (-1, cpw))
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * bits)
    # disjoint bit fields: sum == OR, and sum lowers to one reduction
    return jnp.sum(c << shifts, axis=-1, dtype=jnp.uint32)


def unpack_codes_ref(words: jnp.ndarray, bits: int, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_codes_ref`: (..., nw) uint32 -> (..., n) uint32."""
    cpw = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * bits)
    c = (words[..., :, None] >> shifts) & mask
    return c.reshape(words.shape[:-1] + (-1,))[..., :n]


# ---------------------------------------------------------------------------
# sign wire (signSGD): 1 bit/entry + one global mean-|g| scale
# ---------------------------------------------------------------------------

def pairwise_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Sum over the last axis via a fixed pairwise binary tree.

    Built from elementwise adds on strided slices only -- there is no
    reduce op for XLA to re-associate, so every backend (eager, jit with
    any fusion context, vmap, Mosaic) produces bit-identical f32 partials.
    This is the *defined* accumulation order of the sign wire's scale; the
    sign-pack kernel computes its per-row partials with the same tree.
    Non-power-of-two lengths are zero-padded (exact: s + 0.0 == s for the
    non-negative partials this is used on).
    """
    c = x.shape[-1]
    p = 1 << max(c - 1, 0).bit_length()        # next power of two
    if p != c:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, p - c)])
    while p > 1:
        x = x[..., ::2] + x[..., 1::2]
        p //= 2
    return x[..., 0]


def mean_abs_ref(g: jnp.ndarray) -> jnp.ndarray:
    """mean(|g|) via the canonical two-stage (rows, WIRE_BLOCK) reduction.

    Stage 1: per-row pairwise sums of |g| over WIRE_BLOCK lanes (the
    partials the sign-pack kernel emits); stage 2: pairwise sum across
    rows.  ``jnp.mean`` over the flat vector would drift in the last ulp
    between fusion contexts and break the kernel-vs-oracle exactness
    assertions -- the pairwise tree has exactly one evaluation order.
    """
    n = g.shape[-1]
    pad = (-n) % WIRE_BLOCK
    a = jnp.abs(g.astype(jnp.float32))
    if pad:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)])
    rows = pairwise_sum(a.reshape(a.shape[:-1] + (-1, WIRE_BLOCK)))
    return pairwise_sum(rows) / n


def sign_pack_ref(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """signSGD wire: bit i = (g_i < 0), plus the mean-|g| scale.

    Note the wire semantics at exact zeros: ``jnp.sign(0) = 0`` but a 1-bit
    wire has no zero code, so g == 0 ships as +scale.  Zeros are
    measure-zero in gradients; the codec owns this definition on both the
    encode and reference paths so engine parity is unaffected.
    """
    bits = (g < 0).astype(jnp.uint32)
    return pack_codes_ref(bits, 1), mean_abs_ref(g)


def sign_unpack_ref(words: jnp.ndarray, scale: jnp.ndarray, n: int) -> jnp.ndarray:
    """Reconstruct: +scale where bit == 0, -scale where bit == 1."""
    b = unpack_codes_ref(words, 1, n).astype(jnp.float32)
    return (1.0 - 2.0 * b) * scale


# ---------------------------------------------------------------------------
# quantize+pack wire (FedPAQ / FedQClip block path)
# ---------------------------------------------------------------------------

def quant_pack_ref(
    g: jnp.ndarray, uniforms: jnp.ndarray, block: int = WIRE_BLOCK,
    bits: int = 8,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """block_quant then bias (+levels) and bit-pack in one oracle.

    Returns (words uint32 (n*bits/32 rounded up), scales (n/block,)).
    """
    codes, scales = block_quant_ref(g, uniforms, block, bits)
    levels = int(quant_levels(bits))
    biased = codes.astype(jnp.int32) + levels          # [0, 2*levels]
    return pack_codes_ref(biased, bits), scales


def unpack_dequant_ref(
    words: jnp.ndarray, scales: jnp.ndarray, n: int, block: int = WIRE_BLOCK,
    bits: int = 8,
) -> jnp.ndarray:
    """Inverse wire pass: unpack, un-bias, dequantize.  Returns f32 (n,)."""
    levels = int(quant_levels(bits))
    c = unpack_codes_ref(words, bits, n).astype(jnp.int32) - levels
    return block_dequant_ref(c.astype(jnp.int8), scales, block, bits)


# ---------------------------------------------------------------------------
# coefficient wire (GradESTC / SVDFed): int8 or bf16 coefficients
# ---------------------------------------------------------------------------

def coeff_quant_ref(A: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Deterministic int8 wire for a (k, m) coefficient matrix.

    One max-|.| scale per (row, WIRE_BLOCK-column block); codes are
    round-to-nearest(-even) in [-127, 127].  Deterministic (no stochastic
    rounding) because coefficients are shipped, reconstructed, *and* fed back
    into the client's own basis state -- client and server must agree on the
    exact shipped value, so the roundtrip ``ship`` is returned too.

    Returns (codes int8 (k, m), scales f32 (k, ceil(m/512)), ship f32 (k, m)).
    """
    k, m = A.shape
    pad = (-m) % WIRE_BLOCK
    A32 = A.astype(jnp.float32)
    Ap = jnp.pad(A32, ((0, 0), (0, pad))) if pad else A32
    blocks = Ap.reshape(k, -1, WIRE_BLOCK)
    scales = jnp.maximum(jnp.max(jnp.abs(blocks), axis=2), 1e-12)  # (k, nb)
    x = blocks / scales[:, :, None] * 127.0
    codes = jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8)
    ship = codes.astype(jnp.float32) * (scales[:, :, None] * INV127)
    return (codes.reshape(k, -1)[:, :m], scales,
            ship.reshape(k, -1)[:, :m])


def coeff_dequant_ref(codes: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """(k, m) int8 codes + (k, nb) scales -> (k, m) f32 coefficients."""
    k, m = codes.shape
    pad = (-m) % WIRE_BLOCK
    c = codes.astype(jnp.float32)
    cp = jnp.pad(c, ((0, 0), (0, pad))) if pad else c
    out = cp.reshape(k, -1, WIRE_BLOCK) * (scales[:, :, None] * INV127)
    return out.reshape(k, -1)[:, :m]


def bf16_pack_ref(x: jnp.ndarray) -> jnp.ndarray:
    """f32 (..., n) -> bf16, bitcast to u16, pair-packed into (..., ceil(n/2))
    uint32 wire words (element 2j in the low half-word)."""
    h = jax.lax.bitcast_convert_type(
        x.astype(jnp.bfloat16), jnp.uint16).astype(jnp.uint32)
    return pack_codes_ref(h, 16)


def bf16_unpack_ref(words: jnp.ndarray, n: int) -> jnp.ndarray:
    h = unpack_codes_ref(words, 16, n).astype(jnp.uint16)
    return jax.lax.bitcast_convert_type(h, jnp.bfloat16).astype(jnp.float32)


def encode_quant_ref(
    M: jnp.ndarray, G: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused project + int8-quantize: the SVDFed steady-state uplink oracle.

    A = M^T G, (codes, scales, ship) = coeff_quant(A), and the residual is
    taken against the *shipped* coefficients: E = G - M ship -- the error the
    server actually cannot see, which is what error-feedback must accumulate.

    Returns (codes int8 (k, m), scales f32 (k, ceil(m/512)), E (l, m) G.dtype).
    """
    M32 = M.astype(jnp.float32)
    G32 = G.astype(jnp.float32)
    A = M32.T @ G32
    codes, scales, ship = coeff_quant_ref(A)
    E = G32 - M32 @ ship
    return codes, scales, E.astype(G.dtype)
