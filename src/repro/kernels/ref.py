"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics the kernels must match (asserted across a
shape/dtype sweep in tests/test_kernels.py, kernels run with interpret=True
on CPU).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from .quant import quant_levels

__all__ = ["encode_ref", "decode_ref", "block_quant_ref", "block_dequant_ref"]


def encode_ref(M: jnp.ndarray, G: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused GradESTC projection: A = M^T G, E = G - M A.

    M: (l, k) orthonormal basis.  G: (l, m).  Returns (A (k, m), E (l, m)).
    Accumulation in f32 regardless of input dtype (MXU-accurate semantics).
    """
    M32 = M.astype(jnp.float32)
    G32 = G.astype(jnp.float32)
    A = M32.T @ G32
    E = G32 - M32 @ A
    return A.astype(G.dtype), E.astype(G.dtype)


def decode_ref(M: jnp.ndarray, A: jnp.ndarray) -> jnp.ndarray:
    """GradESTC reconstruction: Ghat = M A.  M: (l, k), A: (k, m)."""
    out = M.astype(jnp.float32) @ A.astype(jnp.float32)
    return out.astype(M.dtype)


def block_quant_ref(
    g: jnp.ndarray, uniforms: jnp.ndarray, block: int, bits: int = 8
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-wise stochastic uniform quantization (TPU-native FedPAQ variant).

    g: (n,) with n % block == 0.  uniforms: (n,) iid U[0,1) used for the
    stochastic rounding.  Each length-``block`` slice gets its own max-abs
    scale (better accuracy than one global scale, and each tile's scale is
    computable inside one VMEM-resident block -- the TPU adaptation).

    Returns (codes int8 in [-(2^(bits-1)-1), 2^(bits-1)-1], scales (n/block,)).
    """
    levels = quant_levels(bits)        # symmetric signed code book
    gb = g.reshape(-1, block).astype(jnp.float32)
    ub = uniforms.reshape(-1, block).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gb), axis=1, keepdims=True), 1e-12)
    x = gb / scale * levels            # [-levels, levels]
    lo = jnp.floor(x)
    codes = lo + (ub < (x - lo)).astype(jnp.float32)
    codes = jnp.clip(codes, -levels, levels)
    return codes.astype(jnp.int8).reshape(g.shape), scale[:, 0]


def block_dequant_ref(
    codes: jnp.ndarray, scales: jnp.ndarray, block: int, bits: int = 8
) -> jnp.ndarray:
    levels = quant_levels(bits)
    cb = codes.reshape(-1, block).astype(jnp.float32)
    return (cb * (scales[:, None] / levels)).reshape(codes.shape)
