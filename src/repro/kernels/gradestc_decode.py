"""Pallas TPU kernel: GradESTC reconstruction  Ghat = M A.

The server-side decompression (Alg. 2 line 2).  A thin blocked GEMM -- kept as
a kernel so that decode shares the same VMEM tiling discipline as encode and
so the benchmark harness can time both sides of the codec.

grid = (l // bl, m // bm); per step the MXU contracts the full k dimension
(k <= 128 always fits).  f32 accumulation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = ["decode_pallas", "decode_wire_pallas"]


def _decode_kernel(m_ref, a_ref, o_ref):
    out = jax.lax.dot_general(
        m_ref[...], a_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_l", "block_m", "interpret"))
def decode_pallas(
    M: jnp.ndarray,
    A: jnp.ndarray,
    *,
    block_l: int = 256,
    block_m: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Ghat = M @ A.  M: (l, k), A: (k, m); l % block_l == m % block_m == 0."""
    l, k = M.shape
    k2, m = A.shape
    assert k == k2
    assert l % block_l == 0 and m % block_m == 0

    grid = (l // block_l, m // block_m)
    return pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_l, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_m), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_l, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((l, m), M.dtype),
        interpret=interpret,
    )(M, A)


# ---------------------------------------------------------------------------
# fused int8-dequant + reconstruction (server side of the int8 coeff wire)
# ---------------------------------------------------------------------------

def _decode_wire_kernel(m_ref, c_ref, s_ref, o_ref):
    A = c_ref[...].astype(jnp.float32) * (s_ref[...] * ref.INV127)  # (k, 512)
    out = jax.lax.dot_general(
        m_ref[...], A, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def decode_wire_pallas(
    M: jnp.ndarray,
    codes: jnp.ndarray,
    scales: jnp.ndarray,
    *,
    block_l: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Ghat = M (codes * scales / 127): dequantize the int8 coefficient wire
    inside the GEMM pass instead of materializing the f32 coefficients.

    M: (l, k), codes: (k, m) int8, scales: (k, m/512);
    l % block_l == 0 and m % 512 == 0 (the wire's scale-block width).
    """
    l, k = M.shape
    k2, m = codes.shape
    assert k == k2 and l % block_l == 0 and m % 512 == 0

    grid = (l // block_l, m // 512)
    return pl.pallas_call(
        _decode_wire_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_l, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, 512), lambda i, j: (0, j)),
            pl.BlockSpec((k, 1), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_l, 512), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((l, m), M.dtype),
        interpret=interpret,
    )(M, codes, scales)
