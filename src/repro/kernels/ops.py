"""Public jit'd wrappers around the Pallas kernels.

Handles: block-shape selection against a VMEM budget, padding to tile
multiples, and backend dispatch -- on TPU the kernels run compiled; elsewhere
(this CPU container) they run in interpret mode or fall through to the
pure-jnp reference (configurable), so the rest of the framework can call one
API unconditionally.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import ref
from .gradestc_decode import decode_pallas
from .gradestc_encode import encode_pallas
from .quant import block_dequant_pallas, block_quant_pallas

__all__ = [
    "encode", "decode", "block_quantize", "block_dequantize",
    "quantize_update", "choose_block_m", "VMEM_BUDGET_BYTES",
]

# v5e VMEM is ~128 MiB/core architecturally but ~16 MiB is the practical
# working budget per pallas_call after double buffering; stay under that.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def choose_block_m(l: int, k: int, dtype=jnp.float32, budget: int = VMEM_BUDGET_BYTES) -> int:
    """Largest 128-multiple bm such that M + G-block + E-block + A-block fit.

    VMEM model (bytes): l*k*s  +  2*l*bm*s  +  k*bm*s,  s = dtype size.
    Returns 0 when even bm=128 cannot fit (l too large for the single-pass
    kernel; ops.encode then falls back to the XLA path, which tiles l
    internally at the cost of reading G twice)."""
    s = jnp.dtype(dtype).itemsize
    fixed = l * k * s
    per_col = (2 * l + k) * s
    bm = (budget - fixed) // per_col
    bm = (bm // 128) * 128
    if bm < 128:
        return 0
    return int(min(bm, 1024))


def _pad_cols(G: jnp.ndarray, mult: int) -> Tuple[jnp.ndarray, int]:
    from repro.core.reshaping import pad_to_block

    Gp, m = pad_to_block(G, mult, axis=-1)
    return Gp, Gp.shape[-1] - m


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def encode(
    M: jnp.ndarray, G: jnp.ndarray, *, use_kernel: bool = True, interpret: bool | None = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused A = M^T G, E = G - M A (see gradestc_encode.py)."""
    if not use_kernel:
        return ref.encode_ref(M, G)
    interp = (not _on_tpu()) if interpret is None else interpret
    l, k = M.shape
    bm = choose_block_m(l, k, G.dtype)
    if bm == 0:
        return ref.encode_ref(M, G)   # l too large for single-pass VMEM
    # Never tile wider than the matrix itself: a small-m G only pays for
    # padding up to the next 128 multiple, not up to the VMEM-budget block.
    m128 = G.shape[1] + ((-G.shape[1]) % 128)
    bm = min(bm, m128)
    Gp, pad = _pad_cols(G, bm)
    A, E = encode_pallas(M, Gp, block_m=bm, interpret=interp)
    if pad:
        A, E = A[:, : G.shape[1]], E[:, : G.shape[1]]
    return A, E


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def decode(
    M: jnp.ndarray, A: jnp.ndarray, *, use_kernel: bool = True, interpret: bool | None = None
) -> jnp.ndarray:
    """Ghat = M @ A (see gradestc_decode.py)."""
    if not use_kernel:
        return ref.decode_ref(M, A)
    interp = (not _on_tpu()) if interpret is None else interpret
    l, k = M.shape
    m = A.shape[1]
    bl = 256 if l % 256 == 0 else (128 if l % 128 == 0 else l)
    # Never tile wider than the coefficient matrix itself: a small-m A only
    # pays for padding to the next 128 multiple (same rule as encode).
    bm = min(256, m + ((-m) % 128))
    Ap, pad = _pad_cols(A, bm)
    out = decode_pallas(M, Ap, block_l=bl, block_m=bm, interpret=interp)
    return out[:, :m] if pad else out


def block_quantize(
    g: jnp.ndarray, key: jax.Array, *, block: int = 512, bits: int = 8,
    use_kernel: bool = True, interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """Flat stochastic int8 quantization.  Returns (codes, scales, pad)."""
    n = g.shape[0]
    pad = (-n) % block
    gp = jnp.pad(g, (0, pad)) if pad else g
    u = jax.random.uniform(key, gp.shape, jnp.float32)
    if not use_kernel:
        codes, scales = ref.block_quant_ref(gp, u, block, bits)
        return codes, scales, pad
    interp = (not _on_tpu()) if interpret is None else interpret
    rows = gp.shape[0] // block
    br = rows if rows < 256 else 256
    while rows % br:
        br -= 1
    codes, scales = block_quant_pallas(
        gp, u, block=block, bits=bits, block_rows=br, interpret=interp
    )
    return codes, scales, pad


def quantize_update(
    g: jnp.ndarray, key: jax.Array, *, bits: int = 8, block: int = 512,
    use_pallas: bool = False, interpret: bool | None = None,
) -> jnp.ndarray:
    """Quantize-dequantize a flat update for the FL quantization codecs
    (FedPAQ, FedQClip) -- the same ``use_pallas`` switch the GradESTC
    encode takes.

    ``use_pallas=False``: the paper's global-max-abs stochastic quantizer
    (one 32-bit scale per tensor; ``core.baselines.quantize_stochastic``).
    ``use_pallas=True``: the TPU-native block-local quantizer
    (``quant.block_quant_pallas``; one 32-bit scale per ``block`` entries,
    interpret mode on CPU).  Returns the server-side reconstruction; byte
    accounting for either wire format lives with the codec
    (``core.codecs.FedPAQCodec.charge_bits``).
    """
    if not use_pallas:
        from repro.core.baselines import dequantize, quantize_stochastic

        codes, scale = quantize_stochastic(g, key, bits)
        return dequantize(codes, scale, bits).astype(g.dtype)
    codes, scales, pad = block_quantize(
        g, key, block=block, bits=bits, use_kernel=True, interpret=interpret
    )
    return block_dequantize(
        codes, scales, pad, block=block, bits=bits, out_dtype=g.dtype
    )


def block_dequantize(
    codes: jnp.ndarray, scales: jnp.ndarray, pad: int, *, block: int = 512,
    bits: int = 8, use_kernel: bool = True, interpret: bool | None = None,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    if not use_kernel:
        out = ref.block_dequant_ref(codes, scales, block, bits)
    else:
        interp = (not _on_tpu()) if interpret is None else interpret
        rows = codes.shape[0] // block
        br = rows if rows < 256 else 256
        while rows % br:
            br -= 1
        out = block_dequant_pallas(
            codes, scales, block=block, bits=bits, block_rows=br,
            interpret=interp, out_dtype=out_dtype,
        )
    return out[: codes.shape[0] - pad] if pad else out
