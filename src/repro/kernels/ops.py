"""Public jit'd wrappers around the Pallas kernels.

Handles: block-shape selection against a VMEM budget, padding to tile
multiples, and backend dispatch -- on TPU the kernels run compiled; elsewhere
(this CPU container) they run in interpret mode or fall through to the
pure-jnp reference (configurable), so the rest of the framework can call one
API unconditionally.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import ref
from .gradestc_decode import decode_pallas, decode_wire_pallas
from .gradestc_encode import encode_pallas, encode_quant_pallas
from .quant import block_dequant_pallas, block_quant_pallas
from .wire import (
    coeff_quant_pallas, quant_pack_pallas, sign_pack_pallas,
    sign_unpack_pallas, unpack_dequant_pallas,
)

__all__ = [
    "encode", "decode", "block_quantize", "block_dequantize",
    "quantize_update", "choose_block_m", "VMEM_BUDGET_BYTES",
    "sign_wire", "sign_unwire", "block_quant_wire", "block_dequant_wire",
    "coeff_quant", "coeff_roundtrip", "encode_quant", "decode_wire",
]

# v5e VMEM is ~128 MiB/core architecturally but ~16 MiB is the practical
# working budget per pallas_call after double buffering; stay under that.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def choose_block_m(l: int, k: int, dtype=jnp.float32, budget: int = VMEM_BUDGET_BYTES) -> int:
    """Largest 128-multiple bm such that M + G-block + E-block + A-block fit.

    VMEM model (bytes): l*k*s  +  2*l*bm*s  +  k*bm*s,  s = dtype size.
    Returns 0 when even bm=128 cannot fit (l too large for the single-pass
    kernel; ops.encode then falls back to the XLA path, which tiles l
    internally at the cost of reading G twice)."""
    s = jnp.dtype(dtype).itemsize
    fixed = l * k * s
    per_col = (2 * l + k) * s
    bm = (budget - fixed) // per_col
    bm = (bm // 128) * 128
    if bm < 128:
        return 0
    return int(min(bm, 1024))


def _pad_cols(G: jnp.ndarray, mult: int) -> Tuple[jnp.ndarray, int]:
    from repro.core.reshaping import pad_to_block

    Gp, m = pad_to_block(G, mult, axis=-1)
    return Gp, Gp.shape[-1] - m


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def encode(
    M: jnp.ndarray, G: jnp.ndarray, *, use_kernel: bool = True, interpret: bool | None = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused A = M^T G, E = G - M A (see gradestc_encode.py)."""
    if not use_kernel:
        return ref.encode_ref(M, G)
    interp = (not _on_tpu()) if interpret is None else interpret
    l, k = M.shape
    bm = choose_block_m(l, k, G.dtype)
    if bm == 0:
        return ref.encode_ref(M, G)   # l too large for single-pass VMEM
    # Never tile wider than the matrix itself: a small-m G only pays for
    # padding up to the next 128 multiple, not up to the VMEM-budget block.
    m128 = G.shape[1] + ((-G.shape[1]) % 128)
    bm = min(bm, m128)
    Gp, pad = _pad_cols(G, bm)
    A, E = encode_pallas(M, Gp, block_m=bm, interpret=interp)
    if pad:
        A, E = A[:, : G.shape[1]], E[:, : G.shape[1]]
    return A, E


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def decode(
    M: jnp.ndarray, A: jnp.ndarray, *, use_kernel: bool = True, interpret: bool | None = None
) -> jnp.ndarray:
    """Ghat = M @ A (see gradestc_decode.py)."""
    if not use_kernel:
        return ref.decode_ref(M, A)
    interp = (not _on_tpu()) if interpret is None else interpret
    l, k = M.shape
    m = A.shape[1]
    bl = 256 if l % 256 == 0 else (128 if l % 128 == 0 else l)
    # Never tile wider than the coefficient matrix itself: a small-m A only
    # pays for padding to the next 128 multiple (same rule as encode).
    bm = min(256, m + ((-m) % 128))
    Ap, pad = _pad_cols(A, bm)
    out = decode_pallas(M, Ap, block_l=bl, block_m=bm, interpret=interp)
    return out[:, :m] if pad else out


def block_quantize(
    g: jnp.ndarray, key: jax.Array, *, block: int = 512, bits: int = 8,
    use_kernel: bool = True, interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """Flat stochastic int8 quantization.  Returns (codes, scales, pad)."""
    n = g.shape[0]
    pad = (-n) % block
    gp = jnp.pad(g, (0, pad)) if pad else g
    u = jax.random.uniform(key, gp.shape, jnp.float32)
    if not use_kernel:
        codes, scales = ref.block_quant_ref(gp, u, block, bits)
        return codes, scales, pad
    interp = (not _on_tpu()) if interpret is None else interpret
    rows = gp.shape[0] // block
    br = rows if rows < 256 else 256
    while rows % br:
        br -= 1
    codes, scales = block_quant_pallas(
        gp, u, block=block, bits=bits, block_rows=br, interpret=interp
    )
    return codes, scales, pad


def quantize_update(
    g: jnp.ndarray, key: jax.Array, *, bits: int = 8, block: int = 512,
    use_pallas: bool = False, interpret: bool | None = None,
) -> jnp.ndarray:
    """Quantize-dequantize a flat update for the FL quantization codecs
    (FedPAQ, FedQClip) -- the same ``use_pallas`` switch the GradESTC
    encode takes.

    Both paths materialize the **packed uint32 wire words** on device and
    reconstruct from them, so what the codec charges the ledger for is what
    actually exists in memory.  The pack/unpack roundtrip is lossless on the
    integer codes, so reconstructions are bit-identical to the pre-wire
    formulation.

    ``use_pallas=False``: the paper's global-max-abs stochastic quantizer
    (one 32-bit scale per tensor; ``core.baselines.quantize_stochastic``),
    packed via the jnp oracle.
    ``use_pallas=True``: the TPU-native block-local quantizer fused with the
    bit-pack (``wire.quant_pack_pallas``; one 32-bit scale per ``block``
    entries, interpret mode on CPU).  Returns the server-side
    reconstruction; byte accounting for either wire format lives with the
    codec (``core.codecs.FedPAQCodec.charge_bits``).
    """
    if not use_pallas:
        from repro.core.baselines import dequantize, quantize_stochastic

        codes, scale = quantize_stochastic(g, key, bits)
        words = ref.pack_codes_ref(codes, bits)
        codes2 = ref.unpack_codes_ref(words, bits, g.shape[0]).astype(jnp.int32)
        return dequantize(codes2, scale, bits).astype(g.dtype)
    words, scales, pad = block_quant_wire(
        g, key, block=block, bits=bits, interpret=interpret
    )
    return block_dequant_wire(
        words, scales, pad, block=block, bits=bits, interpret=interpret,
        out_dtype=g.dtype,
    )


def block_dequantize(
    codes: jnp.ndarray, scales: jnp.ndarray, pad: int, *, block: int = 512,
    bits: int = 8, use_kernel: bool = True, interpret: bool | None = None,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    if not use_kernel:
        out = ref.block_dequant_ref(codes, scales, block, bits)
    else:
        interp = (not _on_tpu()) if interpret is None else interpret
        rows = codes.shape[0] // block
        br = rows if rows < 256 else 256
        while rows % br:
            br -= 1
        out = block_dequant_pallas(
            codes, scales, block=block, bits=bits, block_rows=br,
            interpret=interp, out_dtype=out_dtype,
        )
    return out[: codes.shape[0] - pad] if pad else out


# ---------------------------------------------------------------------------
# packed wire dispatchers (DESIGN.md "Wire-format layer")
# ---------------------------------------------------------------------------
#
# Each dispatcher pads to the (rows, WIRE_BLOCK) kernel layout, picks a row
# tile, and crops the flat wire back to the exact word count the ledger
# charges for.  ``use_kernel=False`` (or a shape/bit-width the kernels do not
# cover) routes to the identical ref.py oracle -- the two paths are
# bit-exact, which tests/test_wire.py asserts per kernel.

def _pick_rows(rows: int) -> int:
    br = rows if rows < 256 else 256
    while rows % br:
        br -= 1
    return br


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def sign_wire(
    g: jnp.ndarray, *, use_kernel: bool = True, interpret: bool | None = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """signSGD uplink: flat g (n,) -> (words uint32 (ceil(n/32),), scale ()).

    scale is mean(|g|) via the canonical two-stage reduction
    (ref.mean_abs_ref); the kernel emits the per-row partials and the final
    sum happens here, so both paths share one float reduction tree.
    """
    n = g.shape[0]
    if not use_kernel:
        return ref.sign_pack_ref(g)
    interp = (not _on_tpu()) if interpret is None else interpret
    pad = (-n) % ref.WIRE_BLOCK
    gp = g.astype(jnp.float32)
    if pad:
        gp = jnp.pad(gp, (0, pad))
    rows = gp.shape[0] // ref.WIRE_BLOCK
    words2, rowsums = sign_pack_pallas(
        gp.reshape(rows, ref.WIRE_BLOCK),
        block_rows=_pick_rows(rows), interpret=interp,
    )
    nw = -(-n // 32)
    return words2.reshape(-1)[:nw], ref.pairwise_sum(rowsums) / n


@functools.partial(jax.jit, static_argnames=("n", "use_kernel", "interpret"))
def sign_unwire(
    words: jnp.ndarray, scale: jnp.ndarray, n: int, *,
    use_kernel: bool = True, interpret: bool | None = None,
) -> jnp.ndarray:
    """Inverse: packed sign bits + scale -> (n,) f32 (+scale / -scale)."""
    if not use_kernel:
        return ref.sign_unpack_ref(words, scale, n)
    interp = (not _on_tpu()) if interpret is None else interpret
    wpr = ref.WIRE_BLOCK // 32
    rows = -(-n // ref.WIRE_BLOCK)
    pad = rows * wpr - words.shape[0]
    wp = jnp.pad(words, (0, pad)) if pad else words
    out = sign_unpack_pallas(
        wp.reshape(rows, wpr), scale,
        block_rows=_pick_rows(rows), interpret=interp,
    )
    return out.reshape(-1)[:n]


def block_quant_wire(
    g: jnp.ndarray, key: jax.Array, *, bits: int = 8, block: int = 512,
    use_kernel: bool = True, interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """Fused block-quantize + bit-pack of a flat update (FedPAQ/FedQClip).

    Returns (words uint32, scales (ceil(n/block),) f32, pad).  The fused
    kernel covers ``block == WIRE_BLOCK`` and ``bits in {2, 4, 8}`` (bit
    widths whose codes tile a 512-lane row evenly); other widths take the
    jnp oracle, so any (bits >= 2, block) stays valid.  bits == 1 is
    rejected: the symmetric signed code book has 2^(bits-1) - 1 = 0 levels
    there -- a 1-bit wire is the *sign* format (``sign_wire``).
    """
    assert bits >= 2, "1-bit quantization is the sign wire (ops.sign_wire)"
    n = g.shape[0]
    pad = (-n) % block
    gp = jnp.pad(g, (0, pad)) if pad else g
    u = jax.random.uniform(key, gp.shape, jnp.float32)
    kernel_ok = (use_kernel and block == ref.WIRE_BLOCK
                 and bits in (2, 4, 8))
    if not kernel_ok:
        words, scales = ref.quant_pack_ref(gp, u, block, bits)
        return words, scales, pad
    interp = (not _on_tpu()) if interpret is None else interpret
    rows = gp.shape[0] // block
    words2, scales = quant_pack_pallas(
        gp.reshape(rows, block).astype(jnp.float32),
        u.reshape(rows, block),
        bits=bits, block_rows=_pick_rows(rows), interpret=interp,
    )
    return words2.reshape(-1), scales, pad


def block_dequant_wire(
    words: jnp.ndarray, scales: jnp.ndarray, pad: int, *, bits: int = 8,
    block: int = 512, use_kernel: bool = True,
    interpret: bool | None = None, out_dtype=jnp.float32,
) -> jnp.ndarray:
    """Inverse wire pass: unpack + un-bias + dequantize, cropping ``pad``."""
    assert bits >= 2, "1-bit codes are the sign wire (ops.sign_unwire)"
    rows = scales.shape[0]
    n_p = rows * block
    kernel_ok = (use_kernel and block == ref.WIRE_BLOCK
                 and bits in (2, 4, 8))
    if not kernel_ok:
        out = ref.unpack_dequant_ref(words, scales, n_p, block, bits)
        out = out.astype(out_dtype)
    else:
        interp = (not _on_tpu()) if interpret is None else interpret
        out = unpack_dequant_pallas(
            words.reshape(rows, -1), scales,
            bits=bits, block_rows=_pick_rows(rows), interpret=interp,
            out_dtype=out_dtype,
        ).reshape(-1)
    return out[: n_p - pad] if pad else out


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def coeff_quant(
    A: jnp.ndarray, *, use_kernel: bool = True, interpret: bool | None = None
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """int8 coefficient wire for a (k, m) matrix: one scale per (row,
    512-column block), deterministic rounding.  Returns (codes int8 (k, m),
    scales (k, ceil(m/512)), ship f32 (k, m))."""
    if not use_kernel:
        return ref.coeff_quant_ref(A)
    interp = (not _on_tpu()) if interpret is None else interpret
    k, m = A.shape
    Ap, pad = _pad_cols(A.astype(jnp.float32), ref.WIRE_BLOCK)
    codes, scales, ship = coeff_quant_pallas(Ap, interpret=interp)
    if pad:
        codes, ship = codes[:, :m], ship[:, :m]
    return codes, scales, ship


@functools.partial(jax.jit, static_argnames=("wire_dtype", "use_kernel", "interpret"))
def coeff_roundtrip(
    A: jnp.ndarray, wire_dtype: str = "f32", *, use_kernel: bool = True,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Ship a coefficient matrix through its wire format and back.

    "f32" is the identity (exact 32-bit wire), "bf16" pair-packs bitcast
    half-words into uint32 (ref oracle -- a cast plus lossless packing),
    "int8" runs the scaled deterministic quantizer.  Client and server both
    see the returned value, so the two basis mirrors stay in sync.
    """
    if wire_dtype == "f32":
        return A
    if wire_dtype == "bf16":
        words = ref.bf16_pack_ref(A)
        return ref.bf16_unpack_ref(words, A.shape[-1]).astype(A.dtype)
    assert wire_dtype == "int8", f"unknown wire_dtype {wire_dtype!r}"
    _, _, ship = coeff_quant(A, use_kernel=use_kernel, interpret=interpret)
    return ship.astype(A.dtype)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def encode_quant(
    M: jnp.ndarray, G: jnp.ndarray, *, use_kernel: bool = True,
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused project + int8 wire: A = M^T G shipped as int8 codes, residual
    against the shipped value (SVDFed's steady-state uplink).

    Returns (codes int8 (k, m), scales (k, ceil(m/512)), E (l, m)).
    """
    if not use_kernel:
        return ref.encode_quant_ref(M, G)
    l, k = M.shape
    if choose_block_m(l, k, G.dtype) < 512:
        return ref.encode_quant_ref(M, G)   # l too large for the 512 tile
    interp = (not _on_tpu()) if interpret is None else interpret
    m = G.shape[1]
    Gp, pad = _pad_cols(G, 512)
    codes, scales, E = encode_quant_pallas(M, Gp, interpret=interp)
    if pad:
        codes, E = codes[:, :m], E[:, :m]
    return codes, scales, E


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def decode_wire(
    M: jnp.ndarray, codes: jnp.ndarray, scales: jnp.ndarray, *,
    use_kernel: bool = True, interpret: bool | None = None,
) -> jnp.ndarray:
    """Ghat = M dequant(codes): the server side of the int8 coefficient
    wire, dequantization fused into the reconstruction GEMM."""
    if not use_kernel:
        return ref.decode_ref(M, ref.coeff_dequant_ref(codes, scales))
    interp = (not _on_tpu()) if interpret is None else interpret
    l, k = M.shape
    m = codes.shape[1]
    cp, pad = _pad_cols(codes, 512)
    bl = 256 if l % 256 == 0 else (128 if l % 128 == 0 else l)
    out = decode_wire_pallas(M, cp, scales, block_l=bl, interpret=interp)
    return out[:, :m] if pad else out
