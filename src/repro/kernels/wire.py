"""Pallas TPU kernels: fused quantize -> bit-pack wire passes.

The codecs' wire formats (DESIGN.md "Wire-format layer") all reduce to the
same canonical uint32 packing: code ``i`` lands in word ``i // cpw`` at shift
``(i % cpw) * bits`` with ``cpw = 32 // bits``.  Done as separate XLA ops the
pipeline materializes a full-precision intermediate between quantize and
pack (and again between unpack and dequantize); each kernel here is one
HBM->VMEM->HBM pass per direction:

  * ``sign_pack_pallas``    -- bit = (g < 0) packed 32/word + per-row |g| sums
                               (signSGD; the dispatcher finishes the two-stage
                               mean so kernel == oracle bit-exactly)
  * ``sign_unpack_pallas``  -- words -> +-scale reconstruction
  * ``quant_pack_pallas``   -- block-quantize (per-512 scale, stochastic
                               rounding) and pack biased codes (FedPAQ/FedQClip)
  * ``unpack_dequant_pallas``-- words + scales -> f32 reconstruction
  * ``coeff_quant_pallas``  -- deterministic int8 wire for (k, m) coefficient
                               matrices, one scale per (row, 512-col block)
                               (GradESTC / SVDFed int8 coefficient wire)
  * ``coeff_dequant_pallas``-- int8 codes + scales -> f32 coefficients

Packing uses strided lane slices (``x[:, c::cpw] << c*bits`` OR-chained, an
unrolled ``cpw``-step loop) rather than a lane-splitting reshape -- Mosaic
handles strided lane access, and the OR chain is a pure VPU op sequence.
Grids tile rows of a ``(rows, 512)`` layout; 512 = 4 f32 lane tiles, so word
counts per row (512/cpw = 16..128) stay lane-aligned.  int8 outputs use the
(32, 128) min tile only for k >= 32; smaller k validates via interpret mode
(this container) and pads on real TPU via the ops.py dispatchers.

All kernels are validated bit-exactly against the ``ref.py`` oracles in
interpret mode (tests/test_wire.py).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref
from .quant import quant_levels

__all__ = [
    "sign_pack_pallas", "sign_unpack_pallas",
    "quant_pack_pallas", "unpack_dequant_pallas",
    "coeff_quant_pallas", "coeff_dequant_pallas",
]

WIRE_BLOCK = 512        # codes per scale row; keep in sync with ref.WIRE_BLOCK


def _pack_rows(codes_u32: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(br, block) unsigned codes -> (br, block//cpw) uint32 words."""
    cpw = 32 // bits
    acc = codes_u32[:, 0::cpw] << 0
    for c in range(1, cpw):
        acc = acc | (codes_u32[:, c::cpw] << (c * bits))
    return acc


def _unpack_rows(words: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(br, nw) uint32 words -> (br, nw*cpw) uint32 codes."""
    cpw = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    cols = [(words >> (c * bits)) & mask for c in range(cpw)]
    # stack -> (br, nw, cpw); merging the trailing dims restores code order
    # j*cpw + c, the canonical layout.
    return jnp.stack(cols, axis=-1).reshape(words.shape[0], -1)


# ---------------------------------------------------------------------------
# sign wire
# ---------------------------------------------------------------------------

def _sign_pack_kernel(g_ref, w_ref, s_ref):
    g = g_ref[...].astype(jnp.float32)                  # (br, 512)
    neg = (g < 0.0).astype(jnp.uint32)
    w_ref[...] = _pack_rows(neg, 1)
    # per-row partials via the canonical pairwise tree (see ref.pairwise_sum)
    s_ref[...] = ref.pairwise_sum(jnp.abs(g))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sign_pack_pallas(
    g2: jnp.ndarray, *, block_rows: int = 256, interpret: bool = False
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """g2: (rows, 512) f32 -> (words (rows, 16) uint32, rowsums (rows,) f32).

    The caller (ops.sign_wire) finishes the scale: sum(rowsums) / n -- the
    same two-stage reduction tree as ref.mean_abs_ref.
    """
    rows, block = g2.shape
    assert block == WIRE_BLOCK and rows % block_rows == 0
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _sign_pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, block // 32), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, block // 32), jnp.uint32),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        interpret=interpret,
    )(g2)


def _sign_unpack_kernel(w_ref, s_ref, o_ref):
    b = _unpack_rows(w_ref[...], 1).astype(jnp.float32)
    o_ref[...] = ((1.0 - 2.0 * b) * s_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sign_unpack_pallas(
    words2: jnp.ndarray, scale: jnp.ndarray, *, block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """words2: (rows, 16) uint32, scale: () f32 -> (rows, 512) f32."""
    rows, nw = words2.shape
    assert nw == WIRE_BLOCK // 32 and rows % block_rows == 0
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _sign_unpack_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, nw), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),     # scale pinned
        ],
        out_specs=pl.BlockSpec((block_rows, WIRE_BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, WIRE_BLOCK), jnp.float32),
        interpret=interpret,
    )(words2, scale.reshape(1, 1).astype(jnp.float32))


# ---------------------------------------------------------------------------
# block-quantize + pack wire (FedPAQ / FedQClip)
# ---------------------------------------------------------------------------

def _quant_pack_kernel(levels, bits, g_ref, u_ref, w_ref, s_ref):
    g = g_ref[...].astype(jnp.float32)                  # (br, 512)
    u = u_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g), axis=1, keepdims=True), 1e-12)
    x = g / scale * levels
    lo = jnp.floor(x)
    codes = lo + (u < (x - lo)).astype(jnp.float32)
    codes = jnp.clip(codes, -levels, levels)
    # codes are exact small integers in f32; bias to [0, 2*levels] (fits
    # ``bits``) and truncate -- identical to the oracle's int path.
    biased = (codes + levels).astype(jnp.uint32)
    w_ref[...] = _pack_rows(biased, bits)
    s_ref[...] = scale[:, 0]


@functools.partial(jax.jit, static_argnames=("bits", "block_rows", "interpret"))
def quant_pack_pallas(
    g2: jnp.ndarray, u2: jnp.ndarray, *, bits: int = 8,
    block_rows: int = 256, interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(rows, 512) f32 -> (words (rows, 512*bits/32) uint32, scales (rows,)).

    One fused pass of the FedPAQ uplink: per-row max-abs scale, stochastic
    rounding against u2, bias, bit-pack.  bits must divide 32 evenly into
    512 (i.e. bits in {1, 2, 4, 8}; ops.py gates other widths to the oracle).
    """
    rows, block = g2.shape
    assert block == WIRE_BLOCK and rows % block_rows == 0
    assert block % (32 // bits) == 0
    nw = block // (32 // bits)
    levels = quant_levels(bits)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_quant_pack_kernel, levels, bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, nw), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, nw), jnp.uint32),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        interpret=interpret,
    )(g2, u2)


def _unpack_dequant_kernel(levels, bits, w_ref, s_ref, o_ref):
    codes = _unpack_rows(w_ref[...], bits).astype(jnp.float32) - levels
    s = s_ref[...]
    # Reciprocal-multiply is the *defined* dequant (see ref.block_dequant_ref)
    inv = float(np.float32(1.0) / np.float32(levels))
    o_ref[...] = (codes * (s[:, None] * inv)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block_rows", "interpret", "out_dtype"))
def unpack_dequant_pallas(
    words2: jnp.ndarray, scales: jnp.ndarray, *, bits: int = 8,
    block_rows: int = 256, interpret: bool = False, out_dtype=jnp.float32,
) -> jnp.ndarray:
    """(rows, 512*bits/32) uint32 + (rows,) scales -> (rows, 512) out_dtype."""
    rows, nw = words2.shape
    assert rows % block_rows == 0 and nw == WIRE_BLOCK // (32 // bits)
    levels = quant_levels(bits)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_unpack_dequant_kernel, levels, bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, nw), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, WIRE_BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, WIRE_BLOCK), out_dtype),
        interpret=interpret,
    )(words2, scales)


# ---------------------------------------------------------------------------
# int8 coefficient wire (GradESTC / SVDFed)
# ---------------------------------------------------------------------------

def _coeff_quant_kernel(a_ref, c_ref, s_ref, p_ref):
    a = a_ref[...].astype(jnp.float32)                  # (k, 512)
    scale = jnp.maximum(jnp.max(jnp.abs(a), axis=1, keepdims=True), 1e-12)
    codes = jnp.clip(jnp.round(a / scale * 127.0), -127.0, 127.0)
    c_ref[...] = codes.astype(jnp.int8)
    s_ref[...] = scale
    p_ref[...] = codes * (scale * ref.INV127)           # shipped value


@functools.partial(jax.jit, static_argnames=("interpret",))
def coeff_quant_pallas(
    A: jnp.ndarray, *, interpret: bool = False
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """A: (k, m) f32, m % 512 == 0 -> (codes int8 (k, m), scales (k, m//512),
    ship f32 (k, m)).  Deterministic round-to-nearest-even (see
    ref.coeff_quant_ref for why the wire must be deterministic here)."""
    k, m = A.shape
    assert m % WIRE_BLOCK == 0
    nb = m // WIRE_BLOCK
    grid = (nb,)
    return pl.pallas_call(
        _coeff_quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((k, WIRE_BLOCK), lambda j: (0, j))],
        out_specs=[
            pl.BlockSpec((k, WIRE_BLOCK), lambda j: (0, j)),
            pl.BlockSpec((k, 1), lambda j: (0, j)),
            pl.BlockSpec((k, WIRE_BLOCK), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, m), jnp.int8),
            jax.ShapeDtypeStruct((k, nb), jnp.float32),
            jax.ShapeDtypeStruct((k, m), jnp.float32),
        ],
        interpret=interpret,
    )(A)


def _coeff_dequant_kernel(c_ref, s_ref, o_ref):
    c = c_ref[...].astype(jnp.float32)
    o_ref[...] = c * (s_ref[...] * ref.INV127)


@functools.partial(jax.jit, static_argnames=("interpret",))
def coeff_dequant_pallas(
    codes: jnp.ndarray, scales: jnp.ndarray, *, interpret: bool = False
) -> jnp.ndarray:
    """codes (k, m) int8 + scales (k, m//512) -> (k, m) f32."""
    k, m = codes.shape
    assert m % WIRE_BLOCK == 0
    grid = (m // WIRE_BLOCK,)
    return pl.pallas_call(
        _coeff_dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, WIRE_BLOCK), lambda j: (0, j)),
            pl.BlockSpec((k, 1), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((k, WIRE_BLOCK), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((k, m), jnp.float32),
        interpret=interpret,
    )(codes, scales)
