"""Pallas TPU kernel: fused (flash) attention for prefill.

Why (EXPERIMENTS.md SPerf, qwen2-vl-72b x prefill_32k): at 32k context the
XLA attention path writes the f32 score/prob matrices to HBM every
(q-chunk x layer) -- the dominant memory-roofline term.  The fused kernel
keeps scores and the running (max, sum) statistics in VMEM: HBM traffic
collapses to q + k + v + o.

Algorithm (standard flash): grid over (batch*kv_head, q blocks); the kernel
body loops over kv blocks with a running log-sum-exp rescale.  GQA-aware --
q arrives grouped (B, KV, G, Sq, hd) so K/V are never repeated.  Causal and
sliding-window masks supported; kv blocks fully above the diagonal are
skipped via masking (compute is still issued -- TPU grids are static -- but
VMEM-local).

Validated in interpret mode against repro.models.layers.attention
(tests/test_kernels.py::TestFlashAttention).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

_NEG = -1e30


def _flash_kernel(causal, window, scale, block_kv, kv_len,
                  q_ref, k_ref, v_ref, o_ref):
    """One (q_block, head) tile.  q_ref: (bq, hd); k/v_ref: (Skv, hd)."""
    bq, hd = q_ref.shape
    qi = pl.program_id(1)           # q-block index
    q = q_ref[...].astype(jnp.float32) * scale

    m0 = jnp.full((bq,), _NEG, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, hd), jnp.float32)

    nkv = kv_len // block_kv

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[...], j * block_kv, block_kv, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[...], j * block_kv, block_kv, 0)
        s = q @ k.astype(jnp.float32).T                     # (bq, bkv)
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_kv), 0)
        kpos = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, (bq, block_kv), 1)
        ok = jnp.ones((bq, block_kv), jnp.bool_)
        if causal:
            ok &= kpos <= qpos
        if window > 0:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p.astype(v.dtype).astype(jnp.float32) @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, nkv, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret",
                     "softmax_scale"),
)
def flash_attention_pallas(
    q: jnp.ndarray,          # (B, Sq, H, hd)
    k: jnp.ndarray,          # (B, Skv, KV, hd)
    v: jnp.ndarray,          # (B, Skv, KV, hd)
    *,
    causal: bool = True,
    window: int = 0,
    softmax_scale: float | None = None,
    block_q: int = 256,
    block_kv: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused attention.  Sq % block_q == 0, Skv % block_kv == 0."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5

    # fold (B, KV, G) into one grid axis; each program sees one head's
    # (block_q, hd) query tile and that kv-head's full (Skv, hd) K/V.
    qg = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4).reshape(
        B * KV * G, Sq, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)

    grid = (B * KV * G, Sq // block_q)
    kernel = functools.partial(
        _flash_kernel, causal, window, scale, block_kv, Skv
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, Skv, hd), lambda h, i: (h // G, 0, 0)),
            pl.BlockSpec((None, Skv, hd), lambda h, i: (h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV * G, Sq, hd), q.dtype),
        interpret=interpret,
    )(qg, kg, vg)
    return out.reshape(B, KV, G, Sq, hd).transpose(0, 3, 1, 2, 4).reshape(
        B, Sq, H, hd)
