"""Pallas TPU kernels for GradESTC hot spots.

  * gradestc_encode -- fused A = M^T G, E = G - M A  (compression hot path)
                       + encode_quant (project -> int8 wire -> residual)
  * gradestc_decode -- blocked Ghat = M A            (server reconstruction)
                       + decode_wire (int8 dequant fused into the GEMM)
  * quant           -- block-wise stochastic int8     (FedPAQ baseline, TPU-native)
  * wire            -- fused quantize -> bit-pack wire passes (sign-pack,
                       quant+pack, int8 coefficient wire) and their inverses
  * flash_attention -- fused causal/window/GQA attention (SPerf, prefill)
  * ops             -- jit'd public wrappers (padding, block-size choice, dispatch)
  * ref             -- pure-jnp oracles (incl. the canonical packed layouts)

Kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU with interpret=True.
"""

from . import ops, ref, wire
from .flash_attention import flash_attention_pallas
from .gradestc_decode import decode_pallas, decode_wire_pallas
from .gradestc_encode import encode_pallas, encode_quant_pallas
from .quant import block_dequant_pallas, block_quant_pallas
from .wire import (
    coeff_dequant_pallas, coeff_quant_pallas, quant_pack_pallas,
    sign_pack_pallas, sign_unpack_pallas, unpack_dequant_pallas,
)

__all__ = [
    "ops", "ref", "wire",
    "encode_pallas", "decode_pallas",
    "encode_quant_pallas", "decode_wire_pallas",
    "block_quant_pallas", "block_dequant_pallas",
    "sign_pack_pallas", "sign_unpack_pallas",
    "quant_pack_pallas", "unpack_dequant_pallas",
    "coeff_quant_pallas", "coeff_dequant_pallas",
    "flash_attention_pallas",
]
