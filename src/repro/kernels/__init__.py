"""Pallas TPU kernels for GradESTC hot spots.

  * gradestc_encode -- fused A = M^T G, E = G - M A  (compression hot path)
  * gradestc_decode -- blocked Ghat = M A            (server reconstruction)
  * quant           -- block-wise stochastic int8     (FedPAQ baseline, TPU-native)
  * flash_attention -- fused causal/window/GQA attention (SPerf, prefill)
  * ops             -- jit'd public wrappers (padding, block-size choice, dispatch)
  * ref             -- pure-jnp oracles

Kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU with interpret=True.
"""

from . import ops, ref
from .flash_attention import flash_attention_pallas
from .gradestc_decode import decode_pallas
from .gradestc_encode import encode_pallas
from .quant import block_dequant_pallas, block_quant_pallas

__all__ = [
    "ops", "ref",
    "encode_pallas", "decode_pallas",
    "block_quant_pallas", "block_dequant_pallas",
    "flash_attention_pallas",
]
