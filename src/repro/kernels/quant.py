"""Pallas TPU kernel: block-wise stochastic int8 quantization (FedPAQ path).

TPU adaptation of FedPAQ's uniform quantizer: instead of one global max-abs
scale (which needs a full-tensor reduction before any packing can start), we
give every VMEM-resident block its own scale.  Each block is then a single
HBM->VMEM->HBM pass: reduce max-abs, scale, stochastically round, emit int8
codes + one f32 scale.  Per-block scales also quantize *more accurately*
(scales adapt to local magnitude), so this is both the TPU-native and the
better-accuracy formulation; EXPERIMENTS.md compares it against the paper's
global-scale FedPAQ.

Randomness: stochastic rounding consumes iid U[0,1) values supplied as an
operand (generated with jax.random outside).  Keeping the PRNG outside the
kernel makes interpret-mode validation bit-exact against ref.py and keeps the
kernel portable across pltpu PRNG revisions.

Layout: g is processed as (rows, block) with one scale per row; grid tiles
rows so each step handles (block_rows, block) elements.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["block_quant_pallas", "block_dequant_pallas", "quant_levels"]


def quant_levels(bits: int) -> float:
    """Magnitude of the symmetric signed code book for ``bits``-bit codes:
    ``2^(bits-1) - 1`` (codes span ``[-levels, levels]``).  The single
    source of truth shared by the Pallas kernels here and the jnp reference
    oracles in ``ref.py`` -- quantize and dequantize must agree on it
    exactly or codes decode at the wrong scale."""
    return float((1 << (bits - 1)) - 1)


def _quant_kernel(levels, g_ref, u_ref, c_ref, s_ref):
    g = g_ref[...].astype(jnp.float32)              # (br, block)
    u = u_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g), axis=1, keepdims=True), 1e-12)
    x = g / scale * levels
    lo = jnp.floor(x)
    codes = lo + (u < (x - lo)).astype(jnp.float32)
    codes = jnp.clip(codes, -levels, levels)
    c_ref[...] = codes.astype(jnp.int8)
    s_ref[...] = scale[:, 0].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block", "bits", "block_rows", "interpret"))
def block_quant_pallas(
    g: jnp.ndarray,
    uniforms: jnp.ndarray,
    *,
    block: int = 512,
    bits: int = 8,
    block_rows: int = 256,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize flat g (n,) -> (codes int8 (n,), scales (n//block,)).

    n % block == 0 and (n//block) % block_rows == 0 (ops.py pads).
    """
    n = g.shape[0]
    assert n % block == 0
    rows = n // block
    assert rows % block_rows == 0
    levels = quant_levels(bits)

    g2 = g.reshape(rows, block)
    u2 = uniforms.reshape(rows, block)
    grid = (rows // block_rows,)
    codes, scales = pl.pallas_call(
        functools.partial(_quant_kernel, levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, block), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, block), jnp.int8),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        interpret=interpret,
    )(g2, u2)
    return codes.reshape(n), scales


def _dequant_kernel(levels, c_ref, s_ref, o_ref):
    c = c_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    # Reciprocal-multiply is the *defined* dequant (see ref.block_dequant_ref)
    inv = float(np.float32(1.0) / np.float32(levels))
    o_ref[...] = (c * (s[:, None] * inv)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "bits", "block_rows", "interpret", "out_dtype"))
def block_dequant_pallas(
    codes: jnp.ndarray,
    scales: jnp.ndarray,
    *,
    block: int = 512,
    bits: int = 8,
    block_rows: int = 256,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    n = codes.shape[0]
    rows = n // block
    levels = quant_levels(bits)
    grid = (rows // block_rows,)
    out = pl.pallas_call(
        functools.partial(_dequant_kernel, levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block), out_dtype),
        interpret=interpret,
    )(codes.reshape(rows, block), scales)
    return out.reshape(n)
