"""Data pipeline: synthetic LM streams + federated (IID / Dirichlet) partitioner."""

from .partition import dirichlet_client_priors, iid_client_priors
from .synthetic import SyntheticLMTask, client_batch_stream, make_task

__all__ = [
    "SyntheticLMTask", "make_task", "client_batch_stream",
    "dirichlet_client_priors", "iid_client_priors",
]
