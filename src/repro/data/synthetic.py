"""Synthetic language-modeling task with controllable client heterogeneity.

The paper evaluates on image classification (MNIST/CIFAR); this framework's
assigned architectures are language/sequence models, so the FL benchmarks use
a *learnable* synthetic LM task (DESIGN.md "Assumptions changed"):

  * a hidden first-order Markov chain over the vocabulary generates token
    streams -- the transition structure is learnable, so training loss
    decreases materially within tens of rounds on a small transformer;
  * each client samples from the chain restricted/reweighted by a per-client
    class prior (classes = vocabulary blocks).  IID -> identical priors;
    Dirichlet(alpha) -> heterogeneous priors, alpha controls skew exactly as
    the paper's alpha in {0.5, 0.1}.

Evaluation: held-out stream drawn from the *uniform* class mixture, metric =
cross-entropy (and top-1 next-token accuracy).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

import numpy as np

from .partition import dirichlet_client_priors, iid_client_priors

__all__ = ["SyntheticLMTask", "make_task", "client_batch_stream"]


@dataclass
class SyntheticLMTask:
    vocab: int
    n_classes: int
    n_clients: int
    trans: np.ndarray           # (V, V) row-stochastic transition matrix
    client_priors: np.ndarray   # (C, n_classes)
    class_of: np.ndarray        # (V,) class id of each token

    def chain_cdf(self, prior: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Per-prior sampling tables: (row-wise transition CDF, start dist).

        Pure function of the (fixed) task and a client prior, so streams
        compute it once at construction instead of on every draw -- the
        tables, not the draw loop, used to dominate per-batch host cost.
        """
        w = prior[self.class_of]                       # (V,)
        trans_w = self.trans * w[None, :]
        trans_w /= trans_w.sum(axis=1, keepdims=True)
        return np.cumsum(trans_w, axis=1), w / w.sum()

    def sample_tokens(
        self, rng: np.random.Generator, batch: int, seq: int, prior: np.ndarray,
        tables: Tuple[np.ndarray, np.ndarray] | None = None,
    ) -> np.ndarray:
        """Sample (batch, seq+1) token ids biased by a class prior."""
        # vectorized chain sampling via inverse-CDF on each row
        cdf, p0 = self.chain_cdf(prior) if tables is None else tables
        x = np.empty((batch, seq + 1), np.int64)
        x[:, 0] = rng.choice(self.vocab, size=batch, p=p0)
        u = rng.random((batch, seq))
        for t in range(seq):
            rows = cdf[x[:, t]]
            x[:, t + 1] = (u[:, t : t + 1] < rows).argmax(axis=1)
        return x


def make_task(
    vocab: int = 256,
    n_classes: int = 8,
    n_clients: int = 10,
    alpha: float | None = None,     # None -> IID
    seed: int = 0,
    concentration: float = 6.0,
) -> SyntheticLMTask:
    rng = np.random.default_rng(seed)
    # sparse-ish learnable transition structure: each token prefers a few
    # successors (sharper rows -> lower achievable CE -> visible learning)
    logits = rng.normal(size=(vocab, vocab))
    top = np.argpartition(-logits, 8, axis=1)[:, :8]
    boost = np.zeros_like(logits)
    np.put_along_axis(boost, top, concentration, axis=1)
    trans = np.exp(logits * 0.3 + boost)
    trans /= trans.sum(axis=1, keepdims=True)

    class_of = rng.integers(0, n_classes, size=vocab)
    if alpha is None:
        priors = iid_client_priors(n_clients, n_classes)
    else:
        priors = dirichlet_client_priors(n_clients, n_classes, alpha, rng)
    return SyntheticLMTask(
        vocab=vocab, n_classes=n_classes, n_clients=n_clients,
        trans=trans, client_priors=priors, class_of=class_of,
    )


def client_batch_stream(
    task: SyntheticLMTask, client: int, batch: int, seq: int, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite stream of {tokens, labels} for one client (-1 = eval/uniform).

    Yields host numpy (token values are identical to the old jnp yields):
    the consumers stack many per-client draws into one round block before
    any device placement, and a per-draw ``jnp.asarray`` put two tiny
    transfers on the host critical path of every round for data that was
    immediately converted back to numpy by the fused engine's assembler.
    """
    rng = np.random.default_rng(hash((seed, client)) % (2**31))
    prior = (
        np.ones(task.n_classes) / task.n_classes
        if client < 0 else task.client_priors[client]
    )
    tables = task.chain_cdf(prior)
    while True:
        x = task.sample_tokens(rng, batch, seq, prior, tables)
        yield {
            "tokens": np.asarray(x[:, :-1], np.int32),
            "labels": np.asarray(x[:, 1:], np.int32),
        }
