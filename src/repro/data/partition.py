"""Federated data partitioning: IID and Dirichlet(alpha) client priors.

Mirrors the paper's experimental setup (Sec. V-A): IID and non-IID with
Dirichlet parameter alpha in {0.5, 0.1}, where alpha controls heterogeneity.
"""

from __future__ import annotations

import numpy as np

__all__ = ["iid_client_priors", "dirichlet_client_priors"]


def iid_client_priors(n_clients: int, n_classes: int) -> np.ndarray:
    return np.full((n_clients, n_classes), 1.0 / n_classes)


def dirichlet_client_priors(
    n_clients: int, n_classes: int, alpha: float,
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    rng = rng or np.random.default_rng(0)
    p = rng.dirichlet([alpha] * n_classes, size=n_clients)
    # guard against degenerate all-zero classes for tiny alpha
    return (p + 1e-6) / (p + 1e-6).sum(axis=1, keepdims=True)
