"""Config registry: the 10 assigned architectures and the 4 input shapes.

Each ``<arch>.py`` module defines ``CONFIG`` with the exact assigned
hyperparameters and a source citation; ``CONFIG.reduced()`` is the smoke-test
variant (<= 2 layers / d_model <= 512 / <= 4 experts).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import SHAPES, ArchConfig, InputShape

_ARCH_MODULES = {
    "llama3-8b": "llama3_8b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "rwkv6-3b": "rwkv6_3b",
    "dbrx-132b": "dbrx_132b",
    "whisper-medium": "whisper_medium",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "gemma3-1b": "gemma3_1b",
    "yi-34b": "yi_34b",
}

#: (arch, shape) pairs skipped with justification (DESIGN.md Sec. 4):
#: long_500k requires a sub-quadratic decode path; pure full-attention
#: architectures have none.
SKIPS: Dict[tuple, str] = {
    ("llama3-8b", "long_500k"): "pure full attention; no sub-quadratic path",
    ("granite-moe-1b-a400m", "long_500k"): "pure full attention; no sub-quadratic path",
    ("tinyllama-1.1b", "long_500k"): "pure full attention; no sub-quadratic path",
    ("dbrx-132b", "long_500k"): "pure full attention; no sub-quadratic path",
    ("whisper-medium", "long_500k"): "full attention enc-dec; 500k ctx out of family scope",
    ("qwen2-vl-72b", "long_500k"): "pure full attention; no sub-quadratic path",
    ("yi-34b", "long_500k"): "pure full attention; no sub-quadratic path",
}


def arch_names() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


def is_skipped(arch: str, shape: str) -> str | None:
    return SKIPS.get((arch, shape))


__all__ = ["arch_names", "get_config", "get_shape", "is_skipped", "SHAPES", "SKIPS"]
