"""rwkv6-3b [ssm] -- Finch, data-dependent decay, attention-free
[arXiv:2404.05892].

32L d_model=2560 (attn-free) d_ff=8960 vocab=65536.  Head size 64
(RWKV convention) -> 40 wkv heads.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,           # d_model / rwkv_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    rwkv_head_dim=64,
    time_decay_extra_dim=64,
    pos_type="none",
    source="arXiv:2404.05892",
)
