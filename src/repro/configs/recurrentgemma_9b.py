"""recurrentgemma-9b [hybrid] -- RG-LRU + local attention, 1:2 pattern
[arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.  Pattern
(rec, rec, local) repeating; local attention window 2048; RG-LRU width
d_rnn = 4096; temporal conv width 4.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    sliding_window=2048,
    layer_pattern=("rec", "rec", "local"),
    d_rnn=4096,
    conv_width=4,
    scale_embed=True,
    source="arXiv:2402.19427",
)
