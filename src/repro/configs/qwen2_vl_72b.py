"""qwen2-vl-72b [vlm] -- M-RoPE, dynamic resolution [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.  The ViT vision
encoder + projector are stubbed per the assignment carve-out:
``vision_tokens`` precomputed patch embeddings prefix the text sequence and
M-RoPE consumes (temporal, height, width) position ids.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    pos_type="mrope",
    rope_theta=1000000.0,
    vision_tokens=256,
    source="arXiv:2409.12191",
)
