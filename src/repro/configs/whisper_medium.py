"""whisper-medium [audio] -- enc-dec, conv frontend (stub) [arXiv:2212.04356].

24L (decoder) d_model=1024 16H d_ff=4096 vocab=51865; 24 encoder layers;
1500 encoder frames (30 s of audio after the stubbed conv frontend).
The assignment lists GQA kv=16 == MHA (whisper uses full MHA).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    encoder_layers=24,
    encoder_seq=1500,
    pos_type="learned",
    norm_eps=1e-5,
    source="arXiv:2212.04356",
)
