"""gemma3-1b [dense] -- 5:1 local:global attention, 128k-capable
[hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144; head_dim 256;
sliding window 512 on local layers; tied embeddings; sqrt(d) embed scaling.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262144,
    head_dim=256,
    sliding_window=512,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    rope_theta=1000000.0,
    scale_embed=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
