"""Optimizers and LR schedules (pure JAX, no external deps).

FedAvg's client optimizer is plain SGD (paper Sec. IV); the server applies
the averaged update with a server learning rate (1.0 = vanilla FedAvg,
momentum > 0 = FedAvgM).  Adam is provided for the centralized-training
driver and ablations.
"""

from .optimizers import OptState, adam, sgd
from .schedules import constant, cosine_decay, linear_warmup

__all__ = ["OptState", "sgd", "adam", "constant", "cosine_decay", "linear_warmup"]
