"""SGD (+momentum) and Adam as (init, update) pure-function pairs.

update(grads, state, params) -> (new_params, new_state); learning rate may be
a float or a callable step -> lr evaluated inside (schedule support).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptState", "sgd", "adam"]

Params = Any
LR = "float | Callable[[jnp.ndarray], jnp.ndarray]"


class OptState(NamedTuple):
    step: jnp.ndarray
    slots: Any                      # optimizer-specific pytree(s)


def _lr_at(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def sgd(lr, momentum: float = 0.0, nesterov: bool = False):
    """Plain / momentum SGD."""

    def init(params: Params) -> OptState:
        slots = (
            jax.tree.map(jnp.zeros_like, params) if momentum else None
        )
        return OptState(step=jnp.zeros((), jnp.int32), slots=slots)

    def update(grads: Params, state: OptState, params: Params) -> Tuple[Params, OptState]:
        step_lr = _lr_at(lr, state.step)

        if momentum:
            vel = jax.tree.map(lambda v, g: momentum * v + g, state.slots, grads)
            eff = (
                jax.tree.map(lambda v, g: momentum * v + g, vel, grads)
                if nesterov else vel
            )
            new = jax.tree.map(
                lambda p, e: (p.astype(jnp.float32) - step_lr * e.astype(jnp.float32)).astype(p.dtype),
                params, eff,
            )
            return new, OptState(step=state.step + 1, slots=vel)

        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - step_lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads,
        )
        return new, OptState(step=state.step + 1, slots=None)

    return init, update


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0):
    """AdamW (decoupled weight decay when weight_decay > 0).

    Moments are stored in f32 regardless of parameter dtype."""

    def init(params: Params) -> OptState:
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            slots=(jax.tree.map(zeros32, params), jax.tree.map(zeros32, params)),
        )

    def update(grads: Params, state: OptState, params: Params) -> Tuple[Params, OptState]:
        m, v = state.slots
        t = state.step + 1
        step_lr = _lr_at(lr, state.step)
        m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g.astype(jnp.float32), m, grads)
        v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * jnp.square(g.astype(jnp.float32)), v, grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, mi, vi):
            upd_ = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
            if weight_decay:
                upd_ = upd_ + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_lr * upd_).astype(p.dtype)

        new = jax.tree.map(upd, params, m, v)
        return new, OptState(step=t, slots=(m, v))

    return init, update
