"""Learning-rate schedules as step -> lr callables."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "linear_warmup", "cosine_decay"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup_steps: int):
    def fn(step):
        frac = jnp.minimum(step.astype(jnp.float32) / max(warmup_steps, 1), 1.0)
        return jnp.asarray(lr, jnp.float32) * frac
    return fn


def cosine_decay(lr: float, total_steps: int, warmup_steps: int = 0, floor: float = 0.0):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup_steps, 1), 1.0) if warmup_steps else 1.0
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.asarray(lr, jnp.float32) * warm * cos
    return fn
