"""Federated-learning round loop (benchmark-scale, single host).

Implements the paper's experimental protocol (Sec. V): N clients, all (or a
sampled fraction) participating per round, each performing ``local_steps``
SGD steps before uploading its model delta through the configured uplink
compression method; the server averages reconstructed deltas and applies
them with a server learning rate (1.0 = FedAvg).

Two round engines share this entry point (DESIGN.md Sec. 8), and both are
generic over the stateless codec protocol (``repro.core.codecs``), so every
method -- GradESTC, the six Table III baselines, and the optional downlink
codec -- runs on either engine:

* ``engine="fused"`` (default) -- the K-round scan-fused engine in
  ``repro/fl/engine.py``: one jitted XLA program per chunk of
  ``scan_rounds`` rounds (a ``lax.scan`` over the branch-free round body),
  local training vmapped over clients, stacked codec state, in-jit
  client selection / aggregation / Formula-13 / downlink compression, one
  packed-stats host sync per chunk.
* ``engine="loop"``  -- the per-client Python reference loop below, kept as
  the parity oracle (identical math, one dispatch per client per group, but
  the same single packed-stats ``host_fetch`` per round -- byte accounting
  shares ``RoundAccountant`` with the fused engine, so it is exact-integer
  on both).

Client selection is a pure function of ``(seed, round)``
(:func:`select_round_clients` -- a ``fold_in`` key chain), so the scan
body derives it in-jit while the host assembles the matching batch blocks
from the same chain; there is no hidden host RNG state.

The distributed SPMD path (pjit over the production mesh) lives in
``repro/launch`` -- this module is the algorithm-fidelity / communication-
accounting harness used by tests, benchmarks, and the examples.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codecs import SERVER_CLIENT_ID
from repro.core.metrics import CommLedger, host_fetch
from repro.core.policy import make_policy
from repro.data import client_batch_stream, make_task
from repro.models import loss_fn, model, param_group_shapes
from repro.models.config import ArchConfig
from repro.optim import sgd

from .compression import (
    RoundAccountant,
    build_codecs,
    build_downlink_codecs,
    make_method,
    pack_round_stats,
    round_base_key,
)

__all__ = ["FLConfig", "FLResult", "run_fl", "default_tiny_arch",
           "make_local_train", "make_eval_step", "make_batched_eval",
           "select_round_clients"]


def select_round_clients(seed: int, rnd, n_clients: int, n_sel: int):
    """The round's selected client ids, sorted -- a pure function of
    ``(seed, round)`` via a ``fold_in`` chain.

    ``rnd`` may be a traced int32, so the scan-fused engine derives the
    selection *inside* the jitted chunk, while the host (batch assembly,
    reference loop) evaluates the identical chain concretely -- both sides
    agree by construction, with no ``np.random.Generator`` state to keep in
    sync."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 0xC11E47), rnd)
    perm = jax.random.permutation(key, n_clients)
    return jnp.sort(perm[:n_sel]).astype(jnp.int32)


def default_tiny_arch(vocab: int = 256) -> ArchConfig:
    """Small-but-real transformer for CPU-scale FL experiments (~1.6M params,
    the LeNet5-of-this-codebase)."""
    return ArchConfig(
        name="fl-tiny", family="dense", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=512, vocab=vocab, dtype="float32", remat=False,
        attn_chunk=0,
    )


@dataclass
class FLConfig:
    method: str = "gradestc"
    rounds: int = 30
    n_clients: int = 10
    participation: float = 1.0       # fraction of clients per round
    local_steps: int = 4
    batch: int = 16
    seq: int = 64
    lr: float = 0.05
    server_lr: float = 1.0
    alpha: Optional[float] = None    # None = IID; 0.5 / 0.1 = paper's non-IID
    #: compress the server->client broadcast through a shared server-side
    #: GradESTC codec (the paper's Sec. VI future work; beyond-paper).
    downlink_compress: bool = False
    seed: int = 0
    eval_every: int = 5
    eval_batches: int = 4
    arch: Optional[ArchConfig] = None
    method_kw: Dict[str, Any] = field(default_factory=dict)
    policy_overrides: Dict[str, tuple] = field(default_factory=dict)
    coverage_target: float = 0.90
    min_params: int = 4096           # tiny model -> lower floor than prod
    #: "fused" = K-round scan chunk engine (engine.py); "loop" = per-client
    #: reference loop (the parity oracle).  Every method, including
    #: downlink compression, runs on either engine.
    engine: str = "fused"
    #: chunk length K of the fused engine: one jitted dispatch and one
    #: packed-stats host sync cover K rounds (``lax.scan`` inside the
    #: chunk program).  Chunks never span an eval round, so trajectories
    #: and the ledger are invariant in K; 1 recovers the per-round fused
    #: engine.  Shapes depend only on the chunk length, so a run compiles
    #: once per distinct length (typically {1, K, remainder}).
    scan_rounds: int = 8
    #: route the compression hot paths through the Pallas kernels -- the
    #: GradESTC A/E projection + reconstruction and the FedPAQ/FedQClip
    #: block quantizer.  None = auto (True on TPU, False elsewhere).
    use_pallas: Optional[bool] = None
    #: data-parallel device count for the fused engine: the selected-client
    #: axis of one round shards over a ("data", "model") mesh
    #: (``launch/mesh.make_fl_mesh``) under ``shard_map``.  None/1 = the
    #: single-device program.  Ledger bytes are identical either way.
    devices: Optional[int] = None


@dataclass
class FLResult:
    eval_rounds: List[int]
    eval_loss: List[float]
    eval_acc: List[float]
    uplink_bytes: List[float]        # cumulative at each eval point
    ledger: CommLedger
    wall_s: float
    extra: Dict[str, Any] = field(default_factory=dict)

    def uplink_at_loss(self, target: float) -> Optional[float]:
        """Cumulative uplink bytes when eval loss first reaches target."""
        for r, l, b in zip(self.eval_rounds, self.eval_loss, self.uplink_bytes):
            if l <= target:
                return b
        return None

    def uplink_at_acc(self, target: float) -> Optional[float]:
        for r, a, b in zip(self.eval_rounds, self.eval_acc, self.uplink_bytes):
            if a >= target:
                return b
        return None


def _flatten_groups(params, groups) -> Dict[str, jnp.ndarray]:
    """{group_path: array} view of the param pytree."""
    out = {}
    for path in groups:
        node = params
        for part in path.split("/"):
            node = node[part]
        out[path] = node
    return out


def _set_groups(params, updates: Dict[str, jnp.ndarray]):
    new = jax.tree.map(lambda x: x, params)   # shallow-copy containers

    def setpath(tree, parts, val):
        if len(parts) == 1:
            tree = dict(tree)
            tree[parts[0]] = val
            return tree
        tree = dict(tree)
        tree[parts[0]] = setpath(tree[parts[0]], parts[1:], val)
        return tree

    for path, val in updates.items():
        new = setpath(new, path.split("/"), val)
    return new


def make_local_train(arch: ArchConfig, lr: float):
    """Jitted ``local_steps`` SGD scan; batches: {k: (steps, B, S)}.

    Shared by both engines -- the fused engine vmaps this exact function over
    the selected-client axis, so per-client math is identical to the loop.
    """
    opt_init, opt_update = sgd(lr)

    @jax.jit
    def local_train(p, batches):
        st = opt_init(p)

        def step(carry, b):
            p, st = carry
            g = jax.grad(lambda pp: loss_fn(arch, pp, b))(p)
            p, st = opt_update(g, st, p)
            return (p, st), None

        (p2, _), _ = jax.lax.scan(step, (p, st), batches)
        return p2

    return local_train


def make_eval_step(arch: ArchConfig):
    @jax.jit
    def eval_step(p, batch):
        logits = model.forward(arch, p, batch)
        labels = batch["labels"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        acc = jnp.mean(jnp.argmax(logits, -1) == labels)
        return jnp.mean(logz - gold), acc

    return eval_step


def make_batched_eval(arch: ArchConfig):
    """One jitted eval over the *stacked* eval block {k: (E, B, S)}.

    Returns a length-2 f32 vector [mean loss, mean acc] so an eval round
    costs exactly one device->host fetch (via ``core.metrics.host_fetch``),
    not one blocking ``float()`` per batch -- the per-batch Python loop was
    the last host-sync storm left in the round engines."""
    eval_step = make_eval_step(arch)

    @jax.jit
    def eval_all(p, batch_block):
        # lax.map, not vmap: one batch of activations live at a time, so
        # raising eval_batches does not multiply peak eval memory.
        ls, accs = jax.lax.map(lambda b: eval_step(p, b), batch_block)
        return jnp.stack([jnp.mean(ls), jnp.mean(accs)]).astype(jnp.float32)

    return eval_all


@dataclass
class _RunSetup:
    """Everything both engines must construct *identically* for parity:
    model/task/policy, per-client data streams, eval batches, and the
    participation count.  Built in exactly one place.  (Client selection is
    not here: it is the stateless :func:`select_round_clients` chain.)"""

    arch: ArchConfig
    task: Any
    params: Any
    groups: Dict[str, tuple]
    group_paths: List[str]
    policy: Any
    method: Any
    streams: Dict[int, Any]
    eval_block: Dict[str, jnp.ndarray]
    eval_fn: Callable
    ledger: CommLedger
    n_sel: int


def _setup_run(cfg: FLConfig) -> _RunSetup:
    arch = cfg.arch or default_tiny_arch()
    task = make_task(vocab=arch.vocab, n_clients=cfg.n_clients, alpha=cfg.alpha,
                     seed=cfg.seed)
    params = model.init_params(arch, jax.random.PRNGKey(cfg.seed))
    groups = param_group_shapes(arch)
    policy = make_policy(groups, overrides=cfg.policy_overrides,
                         coverage_target=cfg.coverage_target,
                         min_params=cfg.min_params)
    method = make_method(cfg.method, policy=policy, seed=cfg.seed, **cfg.method_kw)
    streams = {c: client_batch_stream(task, c, cfg.batch, cfg.seq, cfg.seed)
               for c in range(cfg.n_clients)}
    eval_stream = client_batch_stream(task, -1, cfg.batch, cfg.seq, cfg.seed + 999)
    eval_batches = [next(eval_stream) for _ in range(cfg.eval_batches)]
    eval_block = {k: jnp.stack([b[k] for b in eval_batches])
                  for k in eval_batches[0]}
    return _RunSetup(
        arch=arch, task=task, params=params, groups=groups,
        group_paths=list(groups.keys()), policy=policy, method=method,
        streams=streams, eval_block=eval_block,
        eval_fn=make_batched_eval(arch), ledger=CommLedger(),
        n_sel=max(1, int(round(cfg.participation * cfg.n_clients))),
    )


def run_fl(cfg: FLConfig, progress: Optional[Callable[[int, dict], None]] = None) -> FLResult:
    if cfg.engine not in ("fused", "loop"):
        raise ValueError(f"unknown engine {cfg.engine!r} (want 'fused' or 'loop')")
    if cfg.engine == "fused":
        from .engine import run_fl_fused

        return run_fl_fused(cfg, progress)
    return _run_fl_loop(cfg, progress)


def _run_fl_loop(cfg: FLConfig, progress: Optional[Callable[[int, dict], None]] = None) -> FLResult:
    t0 = time.time()
    su = _setup_run(cfg)
    params = su.params
    eval_fn, eval_block = su.eval_fn, su.eval_block
    streams, ledger = su.streams, su.ledger
    group_paths, n_sel = su.group_paths, su.n_sel
    policy = su.policy
    C = cfg.n_clients

    use_pallas = (jax.default_backend() == "tpu"
                  if cfg.use_pallas is None else cfg.use_pallas)
    codecs = build_codecs(su.method, policy, group_paths, use_pallas, None)
    dl_codecs = (build_downlink_codecs(policy, group_paths, cfg.seed,
                                       use_pallas, None)
                 if cfg.downlink_compress else {})
    acct = RoundAccountant(codecs, dl_codecs, policy, group_paths, n_sel,
                           downlink_enabled=cfg.downlink_compress)

    cstate = {p: c.init_client_state(C) for p, c in codecs.items()}
    shared = {p: c.init_shared_state() for p, c in codecs.items()}
    dl_state = {
        p: jax.tree.map(lambda x: x[0],
                        c.init_client_state(1, client_ids=[SERVER_CLIENT_ID]))
        for p, c in dl_codecs.items()
    }
    dl_shared = {p: c.init_shared_state() for p, c in dl_codecs.items()}
    # One jitted encode per group: the reference loop keeps per-client
    # dispatch granularity (that is what it measures) but not per-op
    # eager overhead.  No static arguments: encode is branch-free across
    # rounds (round-varying config is traced state).
    enc = {p: jax.jit(c.encode) for p, c in codecs.items()}
    upd_shared = {p: jax.jit(c.update_shared) for p, c in codecs.items()}
    dl_enc = {p: jax.jit(c.encode) for p, c in dl_codecs.items()}
    dl_upd_shared = {p: jax.jit(c.update_shared) for p, c in dl_codecs.items()}

    local_train = make_local_train(su.arch, cfg.lr)

    res = FLResult([], [], [], [], ledger, 0.0)
    round_wall = []

    for rnd in range(cfg.rounds):
        t_round = time.perf_counter()
        ledger.begin_round()
        sel = [int(c) for c in
               np.asarray(select_round_clients(cfg.seed, rnd, C, n_sel))]
        base_key = round_base_key(cfg.seed, rnd)

        raw_acc: Dict[str, jnp.ndarray] = {}
        wire_acc: Dict[str, jnp.ndarray] = {}
        stats_rows: Dict[str, list] = {p: [] for p in codecs}
        flat_g = _flatten_groups(params, group_paths)
        for c in sel:
            bs = [next(streams[c]) for _ in range(cfg.local_steps)]
            batches = {k: jnp.stack([b[k] for b in bs]) for k in bs[0]}
            local = local_train(params, batches)
            flat_l = _flatten_groups(local, group_paths)
            for path in group_paths:
                delta = flat_l[path] - flat_g[path]
                codec = codecs.get(path)
                if codec is None:
                    raw_acc[path] = (delta if path not in raw_acc
                                     else raw_acc[path] + delta)
                    continue
                wire = codec.to_wire(delta)
                cst = jax.tree.map(lambda x: x[c], cstate[path])
                ckey = codec.per_client_key(base_key, c)
                cst2, rw, stats = enc[path](cst, shared[path], ckey, wire)
                cstate[path] = jax.tree.map(
                    lambda x, u, _c=c: x.at[_c].set(u), cstate[path], cst2)
                stats_rows[path].append(stats)
                wire_acc[path] = (rw if path not in wire_acc
                                  else wire_acc[path] + rw)

        reds: Dict[str, jnp.ndarray] = {}
        recon_mean: Dict[str, jnp.ndarray] = {}
        for path in group_paths:
            codec = codecs.get(path)
            if codec is None:
                recon_mean[path] = raw_acc[path] / n_sel
                continue
            red = codec.reduce_stats(jnp.stack(stats_rows[path]))
            mean_wire = wire_acc[path] / n_sel
            shared[path] = upd_shared[path](shared[path], red, mean_wire)
            recon_mean[path] = codec.from_wire(
                mean_wire, flat_g[path].shape).astype(flat_g[path].dtype)
            reds[path] = red

        avg = {p: recon_mean[p] * cfg.server_lr for p in group_paths}

        dl_reds: Dict[str, jnp.ndarray] = {}
        for path in group_paths:
            dlc = dl_codecs.get(path)
            if dlc is None:
                continue
            wire = dlc.to_wire(avg[path])
            cst2, rw, stats = dl_enc[path](dl_state[path], dl_shared[path],
                                           base_key, wire)
            dl_state[path] = cst2
            red = dlc.reduce_stats(stats[None])
            dl_shared[path] = dl_upd_shared[path](dl_shared[path], red, rw)
            avg[path] = dlc.from_wire(rw, avg[path].shape).astype(avg[path].dtype)
            dl_reds[path] = red

        params = _set_groups(params, {p: flat_g[p] + avg[p].astype(flat_g[p].dtype)
                                      for p in group_paths})
        jax.block_until_ready(params)

        # ---- the single host sync: same packed layout as the fused engine
        acct.consume(host_fetch(pack_round_stats(reds, dl_reds)), ledger, rnd)
        round_wall.append(time.perf_counter() - t_round)

        if rnd % cfg.eval_every == 0 or rnd == cfg.rounds - 1:
            # one jitted eval over the stacked block, one measured fetch --
            # not one blocking float() per batch.
            la = host_fetch(eval_fn(params, eval_block))
            res.eval_rounds.append(rnd)
            res.eval_loss.append(float(la[0]))
            res.eval_acc.append(float(la[1]))
            res.uplink_bytes.append(ledger.uplink_total)
            if progress:
                progress(rnd, {
                    "loss": res.eval_loss[-1], "acc": res.eval_acc[-1],
                    "uplink": ledger.uplink_total,
                })

    res.wall_s = time.time() - t0
    res.extra["engine"] = "loop"
    res.extra["use_pallas"] = use_pallas
    res.extra["round_wall_s"] = round_wall
    res.extra.update(acct.metrics)
    return res
