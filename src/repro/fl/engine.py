"""Fused client-parallel FL round engine (DESIGN.md Sec. 8).

One FL round == one jitted XLA program:

  * local training is ``vmap``-ed over the selected-client axis (the exact
    ``make_local_train`` step the reference loop uses, so per-client math is
    unchanged);
  * GradESTC compressor state lives as a stacked pytree
    ``{path: (n_clients, L, l, k)}`` instead of per-``(client, path)`` Python
    dicts, so compression for a whole parameter group across all selected
    clients is a single ``vmap(vmap(step))``;
  * reconstruction, client averaging, and the server parameter update happen
    in-jit;
  * exactly **one** device->host transfer leaves the program per round: a
    packed stats vector carrying the per-group uplink scalar counts (exact
    Formula 14 accounting for the ``CommLedger``) and the per-group max
    ``d_r`` / update counts that drive the host-side Formula 13 re-bucketing
    of the candidate count ``d``.

``d`` is a static argument of the compiled round (XLA needs static shapes
for the rSVD sketch), so the engine keeps a host dict ``{path: d}`` and
retraces only when Formula 13 actually moves a group to a new power-of-two
bucket -- the same bounded-recompilation contract as the reference loop.

The per-client Python loop (``simulation._run_fl_loop``) stays as the parity
oracle; ``tests/test_round_engine.py`` pins the two engines to each other.
"""

from __future__ import annotations

import functools
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gradestc as ge
from repro.core.metrics import host_fetch
from repro.core.policy import CompressionPolicy, LayerPlan

from .compression import (
    GradESTCMethod,
    _from_matrices,
    _to_matrices,
    client_layer_keys,
    path_index,
)
from .simulation import (
    FLConfig,
    FLResult,
    _flatten_groups,
    _set_groups,
    _setup_run,
    make_local_train,
)

__all__ = ["run_fl_fused"]


# --------------------------------------------------------------------------
# (client, L, ...) matrix views -- the loop engine's transforms, vmapped
# over the client axis so the "columns = segments" layout lives in exactly
# one place (compression.py) for both engines.
# --------------------------------------------------------------------------

def _stack_to_matrices(v: jnp.ndarray, plan: LayerPlan) -> jnp.ndarray:
    """(C, L, *shape) or (C, *shape) group delta -> (C, L, l, m) matrices."""
    return jax.vmap(lambda x: _to_matrices(x, plan))(v)


# --------------------------------------------------------------------------
# per-(client, layer) compression step
# --------------------------------------------------------------------------

def _make_layer_step(k: int, d: int, variant: str, mode: str, use_pallas: bool,
                     pallas_interpret: Optional[bool]):
    """Single-layer compress step.  Returns ``(M', key', Ghat, d_r, was_init)``.

    ``mode`` statically selects the round's branch structure: the host knows
    deterministically which clients have initialized compressors (a client
    inits on its first selection), so the common rounds compile WITHOUT a
    ``lax.cond`` -- crucial because a vmapped cond lowers to a select that
    executes *both* branches for every (client, layer), i.e. a full extra
    rSVD per steady-state round:

    * ``"init"``   -- every selected client uninitialized (round 0).
    * ``"update"`` -- every selected client initialized (the steady state).
    * ``"mixed"``  -- stragglers under partial participation; keeps the cond.
    """

    def _init(st, G):
        st2, payload, stats = ge.compress_init(st, G, k=k)
        return (st2.M, st2.key, ge.reconstruct(st2.M, payload.coeffs),
                stats.d_r, jnp.ones((), jnp.bool_))

    def _update(st, G):
        st2, payload, stats = ge.compress_update(
            st, G, k=k, d=d, use_pallas=use_pallas,
            pallas_interpret=pallas_interpret,
        )
        return (st2.M, st2.key, ge.reconstruct(st2.M, payload.coeffs),
                stats.d_r, jnp.zeros((), jnp.bool_))

    def _project(st, G):
        # GradESTC-first ablation: frozen basis, coefficients only.
        A = st.M.T @ G
        return (st.M, st.key, st.M @ A,
                jnp.zeros((), jnp.int32), jnp.zeros((), jnp.bool_))

    steady = _project if variant == "first" else _update

    def step(M, key, initialized, G):
        st = ge.CompressorState(M=M, key=key, initialized=initialized)
        if variant == "all" or mode == "init":
            return _init(st, G)
        if mode == "update":
            return steady(st, G)
        return jax.lax.cond(initialized, steady, _init, st, G)

    return step


# --------------------------------------------------------------------------
# the fused round
# --------------------------------------------------------------------------

def _build_round(arch, lr: float, server_lr: float, policy: CompressionPolicy,
                 group_paths, variant: Optional[str], ef: bool,
                 use_pallas: bool, pallas_interpret: Optional[bool]):
    """Returns a jitted ``round_fn(params, state, batches, sel, d_map)``.

    ``d_map`` is a hashable tuple of ``(path, d)`` pairs -- the only static
    input that changes across rounds (bucketed powers of two).  ``state`` is
    the stacked compressor pytree ``(M, keys, initialized, efmem)``.
    """
    local_train = make_local_train(arch, lr)
    compressed = [p for p in group_paths
                  if policy.plans[p].compress] if variant is not None else []

    @functools.partial(jax.jit, static_argnames=("d_map", "mode", "full_part"))
    def round_fn(params, state, batches, sel, d_map, mode, full_part):
        d_of = dict(d_map)
        M, keys, inited, efmem = state

        def take(x):
            return x if full_part else x[sel]

        def put(x, upd):
            return upd if full_part else x.at[sel].set(upd)
        locals_ = jax.vmap(local_train, in_axes=(None, 0))(params, batches)
        flat_g = _flatten_groups(params, group_paths)
        flat_l = _flatten_groups(locals_, group_paths)

        recon_mean: Dict[str, jnp.ndarray] = {}
        stats = {}           # per compressed path: (drmax, n_upd, sum_dr) i32
        for path in group_paths:
            plan = policy.plans.get(path)
            delta = flat_l[path] - flat_g[path][None]          # (C_sel, ...)
            if path not in compressed:
                recon_mean[path] = jnp.sum(delta, 0) / delta.shape[0]
                continue
            k = plan.k
            GL = _stack_to_matrices(delta, plan).astype(jnp.float32)
            if ef:
                GL = GL + take(efmem[path])
            step = _make_layer_step(k, d_of[path], variant, mode, use_pallas,
                                    pallas_interpret)
            M2, K2, Ghat, d_r, was_init = jax.vmap(jax.vmap(step))(
                take(M[path]), take(keys[path]), take(inited[path]), GL
            )
            M = {**M, path: put(M[path], M2)}
            keys = {**keys, path: put(keys[path], K2)}
            inited = {**inited,
                      path: put(inited[path], jnp.ones_like(was_init))}
            if ef:
                efmem = {**efmem, path: put(efmem[path], GL - Ghat)}
            # Per-(client, layer) d_r on update branches; inits (d_r == k)
            # are reported via the n_upd count instead, so the host can
            # reconstruct Formula 14 in exact integer arithmetic.
            upd_dr = jnp.where(was_init, 0, d_r)
            stats[path] = (
                jnp.max(upd_dr).astype(jnp.int32),
                jnp.sum(~was_init).astype(jnp.int32),
                jnp.sum(upd_dr).astype(jnp.int32),
            )
            recon_mean[path] = jax.vmap(
                lambda g: _from_matrices(g, plan, flat_g[path].shape)
            )(Ghat).astype(delta.dtype).sum(0) / delta.shape[0]

        new_flat = {p: flat_g[p] + server_lr * recon_mean[p].astype(flat_g[p].dtype)
                    for p in group_paths}
        new_params = _set_groups(params, new_flat)
        # Packed layout (matched on the host): [drmax, n_upd, sum_dr] per
        # sorted compressed path.  Integer counts only -- the host rebuilds
        # the Formula 14 scalar totals exactly (no f32 accumulation, which
        # would round above 2^24 scalars/round at production client counts).
        flat_stats = [x for p in sorted(stats) for x in stats[p]]
        packed = (jnp.stack(flat_stats) if compressed
                  else jnp.zeros((1,), jnp.int32))
        return new_params, (M, keys, inited, efmem), packed

    return round_fn


def run_fl_fused(cfg: FLConfig,
                 progress: Optional[Callable[[int, dict], None]] = None) -> FLResult:
    t0 = time.time()
    su = _setup_run(cfg)
    arch, params, policy = su.arch, su.params, su.policy
    streams, eval_batches, eval_step = su.streams, su.eval_batches, su.eval_step
    ledger, rng, group_paths, n_sel = su.ledger, su.rng, su.group_paths, su.n_sel
    # The method instance is reused purely as a config parser (variant/ef/
    # alpha/beta defaults) so "gradestc-*" spellings behave identically here.
    method = su.method
    is_ge = isinstance(method, GradESTCMethod)
    variant = method.variant if is_ge else None
    ef = method.ef if is_ge else False

    use_pallas = (jax.default_backend() == "tpu"
                  if cfg.use_pallas is None else cfg.use_pallas)

    comp_paths = [p for p in group_paths if policy.plans[p].compress] if is_ge else []
    pidx = path_index(policy)
    C = cfg.n_clients

    # ---- stacked compressor state ------------------------------------
    M, keys, inited, efmem = {}, {}, {}, {}
    d_of: Dict[str, int] = {}
    for path in comp_paths:
        plan = policy.plans[path]
        L, l, k, m = plan.stack, plan.l, plan.k, plan.m
        M[path] = jnp.zeros((C, L, l, k), jnp.float32)
        keys[path] = jax.vmap(
            lambda c, _i=pidx[path], _L=L: client_layer_keys(cfg.seed, c, _i, _L)
        )(jnp.arange(C))
        inited[path] = jnp.zeros((C, L), jnp.bool_)
        if ef:
            efmem[path] = jnp.zeros((C, L, l, m), jnp.float32)
        d_of[path] = k if variant == "k" else max(1, k // 4)
    state = (M, keys, inited, efmem)

    raw_scalars_per_client = sum(
        policy.plans[p].raw_scalars for p in group_paths if p not in comp_paths
    )
    model_scalars = sum(policy.plans[p].raw_scalars for p in group_paths)

    round_fn = _build_round(arch, cfg.lr, cfg.server_lr, policy, group_paths,
                            variant, ef, use_pallas, None)

    res = FLResult([], [], [], [], ledger, 0.0)
    sum_d = 0
    round_wall = []
    # Host mirror of which clients hold an initialized compressor (a client
    # inits on first selection) -- lets the common rounds compile cond-free.
    client_inited = np.zeros(cfg.n_clients, bool)

    for rnd in range(cfg.rounds):
        t_round = time.perf_counter()
        ledger.begin_round()
        sel = sorted(rng.choice(cfg.n_clients, size=n_sel, replace=False))
        # Assemble the round's (C_sel, steps, B, S) batch block on the host
        # and ship it in one transfer -- not one jnp.stack dispatch per
        # client (the streams yield CPU-backed arrays; np.asarray is cheap).
        per_client = []
        for c in sel:
            bs = [next(streams[c]) for _ in range(cfg.local_steps)]
            per_client.append({kk: np.stack([np.asarray(b[kk]) for b in bs])
                               for kk in bs[0]})
        batches = {kk: jnp.asarray(np.stack([pc[kk] for pc in per_client]))
                   for kk in per_client[0]}
        d_map = tuple(sorted(d_of.items()))
        sel_inited = client_inited[sel]
        mode = ("update" if sel_inited.all()
                else "init" if not sel_inited.any() else "mixed")
        client_inited[sel] = True
        params, state, packed = round_fn(params, state, batches,
                                         jnp.asarray(sel), d_map, mode,
                                         n_sel == cfg.n_clients)

        # ---- the single host sync: ledger charge + Formula 13 --------
        stats = host_fetch(packed)
        uplink = raw_scalars_per_client * n_sel
        for i, path in enumerate(sorted(comp_paths)):
            drmax, n_upd, sum_dr = (int(x) for x in stats[3 * i: 3 * i + 3])
            plan = policy.plans[path]
            n_init = n_sel * plan.stack - n_upd
            # Formula 14 in exact integer arithmetic: inits ship the basis
            # (k*l) + coefficients, updates ship coefficients + the d_r
            # entering vectors and their indices.
            uplink += (n_init * (plan.k * plan.l + plan.k * plan.m)
                       + n_upd * plan.k * plan.m + sum_dr * (plan.l + 1))
            sum_d += plan.k * n_init
            if variant in ("full", "k"):
                sum_d += d_of[path] * n_upd
            if variant == "full" and n_upd > 0:
                d_of[path] = ge.next_candidate_count(
                    drmax, plan.k, method.alpha, method.beta
                )
        ledger.charge_uplink(uplink, group=f"round{rnd}")
        ledger.charge_downlink(model_scalars * n_sel)
        round_wall.append(time.perf_counter() - t_round)

        if rnd % cfg.eval_every == 0 or rnd == cfg.rounds - 1:
            ls, accs = zip(*[eval_step(params, b) for b in eval_batches])
            res.eval_rounds.append(rnd)
            res.eval_loss.append(float(np.mean([float(l) for l in ls])))
            res.eval_acc.append(float(np.mean([float(a) for a in accs])))
            res.uplink_bytes.append(ledger.uplink_total)
            if progress:
                progress(rnd, {"loss": res.eval_loss[-1], "acc": res.eval_acc[-1],
                               "uplink": ledger.uplink_total})

    res.wall_s = time.time() - t0
    res.extra["engine"] = "fused"
    res.extra["use_pallas"] = use_pallas
    res.extra["round_wall_s"] = round_wall
    if is_ge:
        res.extra["sum_d"] = sum_d
    return res
