"""Fused client-parallel FL round engine (DESIGN.md Secs. 8 and 10).

One FL round == one jitted XLA program, for **every** uplink method:

  * local training is ``vmap``-ed over the selected-client axis (the exact
    ``make_local_train`` step the reference loop uses, so per-client math is
    unchanged);
  * compression is method-generic: each parameter group's
    :class:`repro.core.codecs.Codec` is vmapped over the client axis --
    GradESTC's stacked ``(C, L, l, k)`` bases, the per-tensor baselines'
    stacked ``(C, n)`` flat vectors, SVDFed's shared server basis -- so one
    ``vmap(codec.encode)`` covers all selected clients per group;
  * reconstruction, client averaging, the optional in-jit **downlink codec**
    (the shared server-side GradESTC compressor), and the server parameter
    update all happen inside the same program;
  * exactly **one** device->host transfer leaves the program per round: the
    packed int32 stats vector (per-group codec stats, uplink and downlink),
    which :class:`repro.fl.compression.RoundAccountant` -- shared verbatim
    with the reference loop -- turns into exact integer-bit ledger charges
    and the next round's static codec config (Formula 13).

Scaling across a device mesh (``FLConfig.devices > 1``): the same round
runs under ``shard_map`` on a ``("data", "model")`` mesh
(``launch/mesh.make_fl_mesh``), with the *selected-client* axis -- the
vmapped local training, the per-client wire/stats, the gathered slice of
the stacked codec state -- sharded over ``"data"`` and the model params,
codec shared state, and persistent per-client state store replicated.
Cross-shard traffic is exactly: one all-gather of the tiny per-client stats
rows and the updated selected-client codec state, plus one psum of the
masked reconstruction sums -- so the packed stats vector and the single
host sync survive sharding unchanged, and ledger bytes are *identical* to
the single-device program (axis placement comes from
``launch/sharding.FLRoundSpecs``; client counts that do not divide the mesh
are padded with a mirrored client and masked out).

Pipelining the host loop: batch blocks are assembled on a background
double-buffered prefetch thread and ``device_put`` under the batch
sharding; ``params``/``cstate``/``dl_state`` are donated into the round
program; and the packed-stats fetch for round r is deferred one round --
round r+1 dispatches with the current static map and is redispatched only
when Formula 13 actually moves a group to a new power-of-two d bucket
(``FLResult.extra["spec_misses"]``).  Donation and speculative redispatch
conflict by construction (a donated input cannot be replayed), so the
engine donates exactly when no codec has dynamic statics or speculation is
off -- see DESIGN.md Sec. 10.

The per-client Python loop (``simulation._run_fl_loop``) stays as the parity
oracle; ``tests/test_round_engine.py`` and ``tests/test_sharded_engine.py``
pin every engine configuration to it.
"""

from __future__ import annotations

import functools
import queue
import threading
import time
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.codecs import SERVER_CLIENT_ID
from repro.core.metrics import host_fetch

from .compression import (
    RoundAccountant,
    build_codecs,
    build_downlink_codecs,
    pack_round_stats,
    round_base_key,
)
from .simulation import (
    FLConfig,
    FLResult,
    _flatten_groups,
    _set_groups,
    _setup_run,
    make_local_train,
)

__all__ = ["run_fl_fused"]


# ---------------------------------------------------------------------------
# round program builders
# ---------------------------------------------------------------------------

def _build_round(arch, lr: float, server_lr: float, codecs, dl_codecs,
                 group_paths, donate: bool = False):
    """Returns a jitted single-device ``round_fn`` generic over the codecs.

    ``static_map`` / ``dl_static_map`` are hashable ``(path, static)``
    tuples -- the only static inputs that change across rounds (bucketed
    powers of two for GradESTC's ``d``; ``None`` for static-free codecs).
    ``mode`` / ``dl_mode`` statically select the init/update branch
    structure for codecs with an init branch (see ``GradESTCCodec``).
    ``donate`` aliases the params / client-state / downlink-state buffers
    into their round-r+1 successors.
    """
    local_train = make_local_train(arch, lr)

    @functools.partial(jax.jit, static_argnames=(
        "static_map", "dl_static_map", "mode", "dl_mode", "full_part"),
        donate_argnums=(0, 1, 3) if donate else ())
    def round_fn(params, cstate, shared, dl_state, batches, sel, base_key,
                 static_map, dl_static_map, mode, dl_mode, full_part):
        static_of = dict(static_map)

        def take(x):
            return x if full_part else x[sel]

        def put(x, upd):
            return upd if full_part else x.at[sel].set(upd)

        locals_ = jax.vmap(local_train, in_axes=(None, 0))(params, batches)
        flat_g = _flatten_groups(params, group_paths)
        flat_l = _flatten_groups(locals_, group_paths)

        new_cstate, new_shared = dict(cstate), dict(shared)
        recon_mean: Dict[str, jnp.ndarray] = {}
        reds: Dict[str, jnp.ndarray] = {}
        for path in group_paths:
            delta = flat_l[path] - flat_g[path][None]          # (C_sel, ...)
            codec = codecs.get(path)
            if codec is None:
                recon_mean[path] = jnp.sum(delta, 0) / delta.shape[0]
                continue
            wire = jax.vmap(codec.to_wire)(delta)
            ckeys = jax.vmap(
                lambda c, _co=codec: _co.per_client_key(base_key, c)
            )(sel)
            enc = functools.partial(codec.encode,
                                    static=static_of.get(path), mode=mode)
            cst = jax.tree.map(take, cstate[path])
            cst2, recon, stats = jax.vmap(enc, in_axes=(0, None, 0, 0))(
                cst, shared[path], ckeys, wire
            )
            new_cstate[path] = jax.tree.map(put, cstate[path], cst2)
            red = codec.reduce_stats(stats)
            mean_wire = jnp.sum(recon, 0) / delta.shape[0]
            new_shared[path] = codec.update_shared(shared[path], red,
                                                   mean_wire)
            recon_mean[path] = codec.from_wire(
                mean_wire, flat_g[path].shape).astype(delta.dtype)
            reds[path] = red

        avg = {p: recon_mean[p] * server_lr for p in group_paths}
        new_dl_state, dl_reds = _apply_downlink(
            dl_codecs, dl_state, avg, base_key, dict(dl_static_map), dl_mode)
        new_flat = {p: flat_g[p] + avg[p].astype(flat_g[p].dtype)
                    for p in group_paths}
        new_params = _set_groups(params, new_flat)
        packed = pack_round_stats(reds, dl_reds)
        return new_params, new_cstate, new_shared, new_dl_state, packed

    return round_fn


def _apply_downlink(dl_codecs, dl_state, avg, base_key, dl_static_of, dl_mode):
    """Optional downlink codec: the server compresses the aggregated update
    once; every client mirrors the shared decompressor, so the server
    applies the *reconstruction* to stay bit-identical with clients -- all
    in-jit, its stats ride the same packed transfer.  ``avg`` is mutated in
    place.  Shared by the single-device and sharded programs (under
    ``shard_map`` it runs replicated: every shard computes the identical
    server-side encode from the psum'd mean)."""
    new_dl_state = dict(dl_state)
    dl_reds: Dict[str, jnp.ndarray] = {}
    for path, dlc in dl_codecs.items():
        wire = dlc.to_wire(avg[path])
        cst2, recon_w, stats = dlc.encode(
            dl_state[path], (), base_key, wire,
            static=dl_static_of.get(path), mode=dl_mode,
        )
        new_dl_state[path] = cst2
        avg[path] = dlc.from_wire(
            recon_w, avg[path].shape).astype(avg[path].dtype)
        dl_reds[path] = dlc.reduce_stats(stats[None])
    return new_dl_state, dl_reds


def _as_i32(leaf: jnp.ndarray) -> jnp.ndarray:
    """Lossless (C_loc, -1) int32 view of a codec-state leaf, so every
    per-client state update rides *one* fused all-gather regardless of
    dtype mix (f32 bases, uint32 key stacks, bool init flags)."""
    if leaf.dtype == jnp.bool_:
        flat = leaf.astype(jnp.int32)
    else:
        assert leaf.dtype.itemsize == 4, leaf.dtype
        flat = jax.lax.bitcast_convert_type(leaf, jnp.int32)
    return flat.reshape(flat.shape[0], -1)


def _from_i32(col: jnp.ndarray, dtype, shape) -> jnp.ndarray:
    if jnp.dtype(dtype) == jnp.bool_:
        return (col != 0).reshape(shape)
    return jax.lax.bitcast_convert_type(
        col.reshape(shape).astype(jnp.int32), jnp.dtype(dtype))


def _build_sharded_round(arch, lr: float, server_lr: float, codecs, dl_codecs,
                         group_paths, rspecs, n_sel: int,
                         donate: bool = False):
    """The same round as ``_build_round``, under ``shard_map``.

    Per shard: a slice of the padded selected-client axis -- its batch
    block, client ids, and padding mask (``launch/sharding.FLRoundSpecs``
    owns the placement).  Params and all codec state enter replicated
    (``P()``); each shard gathers its selected rows from the replicated
    store locally.  Cross-shard traffic is exactly **two collectives per
    round** (on an oversubscribed CPU mesh every collective is a lockstep
    barrier, so per-group/per-leaf collectives dominated the round until
    they were fused):

      * one ``psum`` of the concatenated mask-weighted reconstruction sums
        (compressed groups' recon wire + raw groups' dense deltas, all f32);
      * one ``all_gather`` of the concatenated per-client int32 row
        [client id | per-group stats | bitcast codec-state update], sliced
        back to the real (unpadded) clients so ``reduce_stats`` sees
        *exactly* the rows the single-device program reduces -- packed
        stats, and therefore ledger bytes, are identical by construction.
        The gathered state columns scatter into the replicated store
        (padded rows mirror client ``sel[0]`` and scatter its identical
        update, so duplicates are benign).

    Everything after the collectives (shared-state update, downlink codec,
    server step) is computed redundantly-replicated on every shard, keeping
    all outputs ``P()``.
    """
    local_train = make_local_train(arch, lr)
    mesh = rspecs.mesh
    ax = rspecs.client_axis_name

    def core(static_of, dl_static_of, mode, dl_mode,
             params, cstate, shared, dl_state, batches, sel, mask, base_key):
        def cmask(x):          # (C_loc,) mask broadcast against x's rank
            return mask.reshape(mask.shape + (1,) * (x.ndim - 1))

        locals_ = jax.vmap(local_train, in_axes=(None, 0))(params, batches)
        flat_g = _flatten_groups(params, group_paths)
        flat_l = _flatten_groups(locals_, group_paths)

        # ---- per-shard phase: encode local clients, stage collective rows
        sums = {}                       # path -> local masked sum (wire/raw)
        int_cols = [sel[:, None].astype(jnp.int32)]
        state_cols: Dict[str, list] = {}
        state_meta: Dict[str, tuple] = {}
        stats_of: Dict[str, jnp.ndarray] = {}
        for path in group_paths:
            delta = flat_l[path] - flat_g[path][None]          # (C_loc, ...)
            codec = codecs.get(path)
            if codec is None:
                sums[path] = jnp.sum(delta * cmask(delta), 0)
                continue
            wire = jax.vmap(codec.to_wire)(delta)
            ckeys = jax.vmap(
                lambda c, _co=codec: _co.per_client_key(base_key, c)
            )(sel)
            enc = functools.partial(codec.encode,
                                    static=static_of.get(path), mode=mode)
            cst = jax.tree.map(lambda x: x[sel], cstate[path])
            cst2, recon, stats = jax.vmap(enc, in_axes=(0, None, 0, 0))(
                cst, shared[path], ckeys, wire
            )
            sums[path] = jnp.sum(recon * cmask(recon), 0)
            int_cols.append(stats)
            leaves, treedef = jax.tree.flatten(cst2)
            state_cols[path] = [_as_i32(lf) for lf in leaves]
            state_meta[path] = (treedef, [lf.shape for lf in leaves],
                                [lf.dtype for lf in leaves])

        # ---- collective 1: fused psum of every group's masked sum --------
        flat_sums = jnp.concatenate(
            [sums[p].reshape(-1).astype(jnp.float32) for p in group_paths])
        flat_sums = jax.lax.psum(flat_sums, ax)
        mean_of: Dict[str, jnp.ndarray] = {}
        off = 0
        for path in group_paths:
            size = int(np.prod(sums[path].shape))
            mean_of[path] = (flat_sums[off: off + size]
                             .reshape(sums[path].shape) / n_sel)
            off += size

        # ---- collective 2: fused all-gather of [sel | stats | state] -----
        for path in state_cols:
            int_cols.extend(state_cols[path])
        gathered = jax.lax.all_gather(
            jnp.concatenate(int_cols, axis=1), ax, axis=0, tiled=True)
        sel_all = gathered[:, 0]
        off = 1
        for path in group_paths:
            codec = codecs.get(path)
            if codec is None:
                continue
            stats_of[path] = gathered[:n_sel, off: off + codec.client_stats_len]
            off += codec.client_stats_len
        new_cstate = dict(cstate)
        for path, (treedef, shapes, dtypes) in state_meta.items():
            upd = []
            for shape, dtype in zip(shapes, dtypes):
                size = int(np.prod(shape[1:], dtype=np.int64))
                col = gathered[:, off: off + size]
                upd.append(_from_i32(col, dtype,
                                     (gathered.shape[0],) + shape[1:]))
                off += size
            new_cstate[path] = jax.tree.map(
                lambda x, u: x.at[sel_all].set(u),
                cstate[path], jax.tree.unflatten(treedef, upd))

        # ---- replicated phase: identical on every shard ------------------
        new_shared = dict(shared)
        recon_mean: Dict[str, jnp.ndarray] = {}
        reds: Dict[str, jnp.ndarray] = {}
        for path in group_paths:
            codec = codecs.get(path)
            if codec is None:
                recon_mean[path] = mean_of[path]
                continue
            red = codec.reduce_stats(stats_of[path])
            new_shared[path] = codec.update_shared(shared[path], red,
                                                   mean_of[path])
            recon_mean[path] = codec.from_wire(
                mean_of[path], flat_g[path].shape).astype(flat_g[path].dtype)
            reds[path] = red

        avg = {p: recon_mean[p] * server_lr for p in group_paths}
        new_dl_state, dl_reds = _apply_downlink(
            dl_codecs, dl_state, avg, base_key, dl_static_of, dl_mode)
        new_flat = {p: flat_g[p] + avg[p].astype(flat_g[p].dtype)
                    for p in group_paths}
        new_params = _set_groups(params, new_flat)
        packed = pack_round_stats(reds, dl_reds)
        return new_params, new_cstate, new_shared, new_dl_state, packed

    @functools.partial(jax.jit, static_argnames=(
        "static_map", "dl_static_map", "mode", "dl_mode"),
        donate_argnums=(0, 1, 3) if donate else ())
    def round_fn(params, cstate, shared, dl_state, batches, sel, mask,
                 base_key, static_map, dl_static_map, mode, dl_mode):
        fn = functools.partial(core, dict(static_map), dict(dl_static_map),
                               mode, dl_mode)
        smapped = shard_map(
            fn, mesh=mesh,
            in_specs=(P(), P(), P(), P(), rspecs.batch(batches),
                      rspecs.client_vec, rspecs.client_vec, P()),
            out_specs=(P(), P(), P(), P(), P()),
            check_rep=False,
        )
        return smapped(params, cstate, shared, dl_state, batches, sel, mask,
                       base_key)

    return round_fn


# ---------------------------------------------------------------------------
# host-side round prefetcher
# ---------------------------------------------------------------------------

class _RoundItem(NamedTuple):
    sel: np.ndarray                       # (n_sel,) selected client ids
    mode: str                             # "init" | "update" | "mixed"
    batches: Dict[str, jnp.ndarray]       # (C_pad, steps, B, S) on device
    sel_dev: jnp.ndarray                  # (C_pad,) int32 on device
    mask_dev: Optional[jnp.ndarray]       # (C_pad,) f32 (sharded runs only)


class _RoundPrefetcher:
    """Assembles each round's batch block off the critical path.

    Owns the *entire* host side of round construction so it is bit-identical
    to the reference loop: the selection rng, the per-client stream draws
    (same order: per round, per selected client, ``local_steps`` nexts), and
    the host mirror of which clients hold an initialized compressor (a
    client inits on first selection -- deterministic, so the mode of a
    future round is known at prefetch time).  With ``threaded=True`` a
    daemon worker keeps a double buffer (queue depth 2) of device-resident
    rounds, ``jax.device_put`` under the batch sharding.
    """

    def __init__(self, cfg: FLConfig, streams, rng, n_sel: int,
                 has_init: bool, place: Callable, threaded: bool):
        self.cfg = cfg
        self.streams = streams
        self.rng = rng
        self.n_sel = n_sel
        self.has_init = has_init
        self.place = place
        self.client_inited = np.zeros(cfg.n_clients, bool)
        self._q: Optional[queue.Queue] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if threaded:
            self._q = queue.Queue(maxsize=2)
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _assemble(self) -> _RoundItem:
        cfg = self.cfg
        sel = np.asarray(
            sorted(self.rng.choice(cfg.n_clients, size=self.n_sel,
                                   replace=False)), np.int32)
        per_client = []
        for c in sel:
            bs = [next(self.streams[int(c)]) for _ in range(cfg.local_steps)]
            per_client.append({kk: np.stack([np.asarray(b[kk]) for b in bs])
                               for kk in bs[0]})
        block = {kk: np.stack([pc[kk] for pc in per_client])
                 for kk in per_client[0]}
        if self.has_init:
            sel_inited = self.client_inited[sel]
            mode = ("update" if sel_inited.all()
                    else "init" if not sel_inited.any() else "mixed")
            self.client_inited[sel] = True
        else:
            mode = "update"
        batches, sel_dev, mask_dev = self.place(block, sel)
        return _RoundItem(sel, mode, batches, sel_dev, mask_dev)

    def _put(self, item) -> bool:
        """Stop-aware put, so an abandoned driver cannot strand the worker
        blocked on a full queue (holding device-resident batch blocks)."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.2)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self) -> None:
        try:
            for _ in range(self.cfg.rounds):
                if not self._put(self._assemble()):
                    return
        except BaseException as e:          # surfaced on the next get()
            self._put(e)

    def get(self) -> _RoundItem:
        if self._q is None:
            return self._assemble()
        item = self._q.get()
        if isinstance(item, BaseException):
            raise item
        return item

    def close(self) -> None:
        """Release the worker and any buffered device blocks (idempotent;
        a no-op on the clean path where all rounds were consumed)."""
        if self._q is None:
            return
        self._stop.set()
        for _ in range(2):
            while True:
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    break
            if self._thread is not None:
                self._thread.join(timeout=1.0)


def _single_device_place(block, sel):
    return ({k: jnp.asarray(v) for k, v in block.items()},
            jnp.asarray(sel), None)


def _sharded_place(rspecs, block, sel):
    """Pad the selected axis to the shard count (mirroring client ``sel[0]``
    so padded lanes compute a benign duplicate) and place every per-client
    array under its ``FLRoundSpecs`` sharding."""
    c_sel = int(sel.shape[0])
    c_pad = rspecs.pad_clients(c_sel)
    mask = np.zeros((c_pad,), np.float32)
    mask[:c_sel] = 1.0
    if c_pad > c_sel:
        reps = c_pad - c_sel
        block = {k: np.concatenate([v, np.repeat(v[:1], reps, axis=0)])
                 for k, v in block.items()}
        sel = np.concatenate([sel, np.repeat(sel[:1], reps)])
    return (rspecs.put_batch(block), rspecs.put_client_vec(sel),
            rspecs.put_client_vec(mask))


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_fl_fused(cfg: FLConfig,
                 progress: Optional[Callable[[int, dict], None]] = None) -> FLResult:
    t0 = time.time()
    su = _setup_run(cfg)
    arch, params, policy = su.arch, su.params, su.policy
    eval_fn, eval_block = su.eval_fn, su.eval_block
    ledger, rng, group_paths, n_sel = su.ledger, su.rng, su.group_paths, su.n_sel

    use_pallas = (jax.default_backend() == "tpu"
                  if cfg.use_pallas is None else cfg.use_pallas)
    C = cfg.n_clients
    ndev = int(cfg.devices or 1)

    codecs = build_codecs(su.method, policy, group_paths, use_pallas, None)
    dl_codecs = (build_downlink_codecs(policy, group_paths, cfg.seed,
                                       use_pallas, None)
                 if cfg.downlink_compress else {})
    acct = RoundAccountant(codecs, dl_codecs, policy, group_paths, n_sel,
                           downlink_enabled=cfg.downlink_compress)
    # A donated input cannot be replayed, and a speculation miss replays the
    # round with corrected statics -- so donate exactly when a miss is
    # impossible (no dynamic statics) or speculation is off (DESIGN.md
    # Sec. 10, "donation vs speculation").
    speculate = bool(cfg.speculate)
    donate = not (speculate and acct.has_dynamic_statics)

    cstate = {p: c.init_client_state(C) for p, c in codecs.items()}
    shared = {p: c.init_shared_state() for p, c in codecs.items()}
    dl_state = {
        p: jax.tree.map(lambda x: x[0],
                        c.init_client_state(1, client_ids=[SERVER_CLIENT_ID]))
        for p, c in dl_codecs.items()
    }

    if ndev > 1:
        from repro.launch.mesh import make_fl_mesh
        from repro.launch.sharding import FLRoundSpecs, make_plan

        mesh = make_fl_mesh(ndev)
        rspecs = FLRoundSpecs(make_plan(mesh, arch))
        # Commit everything replicated up front so donated buffers alias
        # across rounds instead of being re-laid-out on first use.
        params = rspecs.put_replicated(params)
        cstate = rspecs.put_replicated(cstate)
        shared = rspecs.put_replicated(shared)
        dl_state = rspecs.put_replicated(dl_state)
        round_fn = _build_sharded_round(arch, cfg.lr, cfg.server_lr, codecs,
                                        dl_codecs, group_paths, rspecs,
                                        n_sel, donate)
        place = functools.partial(_sharded_place, rspecs)
    else:
        round_fn = _build_round(arch, cfg.lr, cfg.server_lr, codecs,
                                dl_codecs, group_paths, donate)
        place = _single_device_place

    has_init = any(c.has_init_branch for c in codecs.values())
    dl_has_init = any(c.has_init_branch for c in dl_codecs.values())
    prefetcher = _RoundPrefetcher(cfg, su.streams, rng, n_sel, has_init,
                                  place, threaded=bool(cfg.prefetch))

    res = FLResult([], [], [], [], ledger, 0.0)
    round_wall = []
    spec_misses = 0
    pending = None          # (packed stats device array, round index)

    def drain():
        nonlocal pending
        if pending is not None:
            acct.consume(host_fetch(pending[0]), ledger, pending[1])
            pending = None

    try:
        for rnd in range(cfg.rounds):
            t_round = time.perf_counter()
            ledger.begin_round()
            item = prefetcher.get()
            dl_mode = "init" if (dl_has_init and rnd == 0) else "update"
            base_key = round_base_key(cfg.seed, rnd)

            def dispatch(maps, _i=item, _bk=base_key, _dm=dl_mode):
                up_map, dl_map = maps
                if ndev > 1:
                    return round_fn(params, cstate, shared, dl_state, _i.batches,
                                    _i.sel_dev, _i.mask_dev, _bk, up_map, dl_map,
                                    _i.mode, _dm)
                return round_fn(params, cstate, shared, dl_state, _i.batches,
                                _i.sel_dev, _bk, up_map, dl_map, _i.mode, _dm,
                                n_sel == C)

            if pending is None or not speculate:
                drain()                       # statics now exact
                out = dispatch(acct.static_args())
            else:
                # Speculate across the deferred fetch: dispatch round r with the
                # static map as of round r-2's stats, then validate against
                # round r-1's.  The dispatch overlaps the previous round's
                # device compute and the stats D2H transfer.
                maps_spec = acct.static_args()
                out = dispatch(maps_spec)
                drain()
                maps_true = acct.static_args()
                if maps_true != maps_spec:
                    if donate:                # unreachable: donate => static maps
                        raise RuntimeError("speculation miss with donated inputs")
                    spec_misses += 1
                    out = dispatch(maps_true)
            params, cstate, shared, dl_state, packed = out
            pending = (packed, rnd)
            if hasattr(packed, "copy_to_host_async"):
                packed.copy_to_host_async()   # overlap the D2H with round r+1
            round_wall.append(time.perf_counter() - t_round)

            if rnd % cfg.eval_every == 0 or rnd == cfg.rounds - 1:
                drain()                       # ledger exact before reporting
                la = host_fetch(eval_fn(params, eval_block))
                res.eval_rounds.append(rnd)
                res.eval_loss.append(float(la[0]))
                res.eval_acc.append(float(la[1]))
                res.uplink_bytes.append(ledger.uplink_total)
                if progress:
                    progress(rnd, {"loss": res.eval_loss[-1], "acc": res.eval_acc[-1],
                                   "uplink": ledger.uplink_total})
        drain()
    finally:
        prefetcher.close()

    res.wall_s = time.time() - t0
    res.extra["engine"] = "fused"
    res.extra["use_pallas"] = use_pallas
    res.extra["round_wall_s"] = round_wall
    res.extra["devices"] = ndev
    res.extra["speculate"] = speculate
    res.extra["spec_misses"] = spec_misses
    res.extra["donated_buffers"] = donate
    res.extra.update(acct.metrics)
    return res
