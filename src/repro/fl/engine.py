"""K-round scan-fused client-parallel FL round engine (DESIGN.md Secs. 8-11).

One jitted XLA program covers a **chunk of K rounds** (``FLConfig.
scan_rounds``), for **every** uplink method:

  * the chunk body is a ``lax.scan`` whose step is one complete FL round:
    in-jit client selection from a folded key chain
    (``simulation.select_round_clients``), vmapped local training, the
    method-generic codec encode (``vmap(codec.encode)`` over clients),
    reconstruction, client averaging, the optional in-jit downlink codec,
    and the server parameter update;
  * the round body is **branch-free across rounds**: there are no
    jit-static per-round arguments left.  GradESTC's Formula-13 candidate
    count ``d`` is traced shared state masking rank-padded buffers
    (``core/gradestc.compress_step``), and init / steady / mixed
    partial-participation rounds all take the same code path -- so the
    scan's single trace serves every round and nothing recompiles mid-run;
  * the scan stacks each round's packed int32 stats vector into a
    ``(K, stats_len)`` block, and exactly **one** device->host transfer
    leaves the program per chunk: that block, which
    :class:`repro.fl.compression.RoundAccountant` -- shared verbatim with
    the reference loop -- turns row by row into exact integer-bit ledger
    charges.

The host loop therefore dispatches once per chunk and syncs once per K
rounds.  Chunks never span an eval round (``plan_chunks``), so parameters
materialize exactly at eval points and trajectories / ledger bytes are
invariant in K; a run compiles one executable per distinct chunk length
(typically {1, K, remainder} -- measured via ``FLResult.extra
["chunk_compiles"]``).  The chunk's stats fetch is deferred one chunk so
the D2H transfer and the host-side accounting overlap the next chunk's
device compute; all chunk inputs are donated (nothing is ever replayed --
the speculation / spec-miss / donation-suppression machinery of the old
per-round pipelined engine is gone, because the statics it speculated on
no longer exist).

Scaling across a device mesh (``FLConfig.devices > 1``): the same chunk
runs under ``shard_map`` on a ``("data", "model")`` mesh
(``launch/mesh.make_fl_mesh``) with the scan *inside* the shard_map body.
The selected-client axis -- local training, per-client wire/stats, the
gathered slice of the stacked codec state -- shards over ``"data"``; model
params, codec shared state, and the persistent per-client state store stay
replicated.  Cross-shard traffic is exactly two collectives per round (one
psum of the concatenated masked reconstruction sums, one all_gather of the
[stats | bitcast state] int32 rows), so the stacked stats block and
the single per-chunk host sync survive sharding unchanged and ledger bytes
are *identical* to the single-device program.  Client counts that do not
divide the mesh are padded in-jit with a mirrored client and masked out.

The per-client Python loop (``simulation._run_fl_loop``) stays as the
parity oracle; ``tests/test_round_engine.py`` and
``tests/test_sharded_engine.py`` pin every engine configuration to it.
"""

from __future__ import annotations

import functools
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.codecs import SERVER_CLIENT_ID
from repro.core.metrics import host_fetch

from .compression import (
    RoundAccountant,
    build_codecs,
    build_downlink_codecs,
    pack_round_stats,
    round_base_key,
)
from .simulation import (
    FLConfig,
    FLResult,
    _flatten_groups,
    _set_groups,
    _setup_run,
    make_local_train,
    select_round_clients,
)

__all__ = ["run_fl_fused", "plan_chunks"]


# ---------------------------------------------------------------------------
# chunk planning
# ---------------------------------------------------------------------------

def plan_chunks(rounds: int, eval_every: int, scan_rounds: int
                ) -> List[Tuple[int, int]]:
    """Partition ``range(rounds)`` into scan chunks ``[start, end)``.

    A chunk grows until it holds ``scan_rounds`` rounds or its last round
    is an eval round (``r % eval_every == 0 or r == rounds - 1``), whichever
    comes first -- so parameters always materialize exactly at eval points
    and the eval cadence is invariant in K.  The resulting chunk lengths
    take at most three distinct values ({1, K, remainder} in the common
    case), each of which compiles exactly once.
    """
    scan_rounds = max(1, int(scan_rounds))
    chunks: List[Tuple[int, int]] = []
    start = 0
    while start < rounds:
        end = start
        for r in range(start, min(start + scan_rounds, rounds)):
            end = r + 1
            if r % eval_every == 0 or r == rounds - 1:
                break
        chunks.append((start, end))
        start = end
    return chunks


# ---------------------------------------------------------------------------
# chunk program builders
# ---------------------------------------------------------------------------

def _apply_downlink(dl_codecs, dl_state, dl_shared, avg, base_key):
    """Optional downlink codec: the server compresses the aggregated update
    once; every client mirrors the shared decompressor, so the server
    applies the *reconstruction* to stay bit-identical with clients -- all
    in-jit, its stats ride the same packed transfer.  ``avg`` is mutated in
    place.  Shared by the single-device and sharded programs (under
    ``shard_map`` it runs replicated: every shard computes the identical
    server-side encode from the psum'd mean)."""
    new_dl_state, new_dl_shared = dict(dl_state), dict(dl_shared)
    dl_reds: Dict[str, jnp.ndarray] = {}
    for path, dlc in dl_codecs.items():
        wire = dlc.to_wire(avg[path])
        cst2, recon_w, stats = dlc.encode(dl_state[path], dl_shared[path],
                                          base_key, wire)
        new_dl_state[path] = cst2
        red = dlc.reduce_stats(stats[None])
        new_dl_shared[path] = dlc.update_shared(dl_shared[path], red, recon_w)
        avg[path] = dlc.from_wire(
            recon_w, avg[path].shape).astype(avg[path].dtype)
        dl_reds[path] = red
    return new_dl_state, new_dl_shared, dl_reds


def _build_chunk(arch, lr: float, server_lr: float, codecs, dl_codecs,
                 group_paths, seed: int, n_clients: int, n_sel: int):
    """Returns the jitted single-device ``chunk_fn``: a ``lax.scan`` of the
    branch-free round body over the chunk's stacked batch blocks.  All
    carried state (params, codec client/shared state, downlink state) is
    donated -- nothing is ever redispatched."""
    local_train = make_local_train(arch, lr)
    full_part = (n_sel == n_clients)

    def round_body(carry, xs):
        params, cstate, shared, dl_state, dl_shared = carry
        batches, rnd = xs                      # batches: {k: (C_sel, ...)}
        sel = select_round_clients(seed, rnd, n_clients, n_sel)
        base_key = round_base_key(seed, rnd)

        def take(x):
            return x if full_part else x[sel]

        def put(x, upd):
            return upd if full_part else x.at[sel].set(upd)

        locals_ = jax.vmap(local_train, in_axes=(None, 0))(params, batches)
        flat_g = _flatten_groups(params, group_paths)
        flat_l = _flatten_groups(locals_, group_paths)

        new_cstate, new_shared = dict(cstate), dict(shared)
        recon_mean: Dict[str, jnp.ndarray] = {}
        reds: Dict[str, jnp.ndarray] = {}
        for path in group_paths:
            delta = flat_l[path] - flat_g[path][None]          # (C_sel, ...)
            codec = codecs.get(path)
            if codec is None:
                recon_mean[path] = jnp.sum(delta, 0) / delta.shape[0]
                continue
            wire = jax.vmap(codec.to_wire)(delta)
            ckeys = jax.vmap(
                lambda c, _co=codec: _co.per_client_key(base_key, c)
            )(sel)
            cst = jax.tree.map(take, cstate[path])
            cst2, recon, stats = jax.vmap(
                codec.encode, in_axes=(0, None, 0, 0)
            )(cst, shared[path], ckeys, wire)
            new_cstate[path] = jax.tree.map(put, cstate[path], cst2)
            red = codec.reduce_stats(stats)
            mean_wire = jnp.sum(recon, 0) / delta.shape[0]
            new_shared[path] = codec.update_shared(shared[path], red,
                                                   mean_wire)
            recon_mean[path] = codec.from_wire(
                mean_wire, flat_g[path].shape).astype(delta.dtype)
            reds[path] = red

        avg = {p: recon_mean[p] * server_lr for p in group_paths}
        new_dl_state, new_dl_shared, dl_reds = _apply_downlink(
            dl_codecs, dl_state, dl_shared, avg, base_key)
        new_flat = {p: flat_g[p] + avg[p].astype(flat_g[p].dtype)
                    for p in group_paths}
        new_params = _set_groups(params, new_flat)
        packed = pack_round_stats(reds, dl_reds)
        return (new_params, new_cstate, new_shared, new_dl_state,
                new_dl_shared), packed

    # Only the carried state is donated: the int32 batch block has no
    # same-shape output to alias with, so donating it just trips XLA's
    # unusable-donation warning every chunk.
    @functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4))
    def chunk_fn(params, cstate, shared, dl_state, dl_shared, batches,
                 round_ids):
        carry, packed = jax.lax.scan(
            round_body, (params, cstate, shared, dl_state, dl_shared),
            (batches, round_ids))
        return carry + (packed,)

    return chunk_fn


def _as_i32(leaf: jnp.ndarray) -> jnp.ndarray:
    """Lossless (C_loc, -1) int32 view of a codec-state leaf, so every
    per-client state update rides *one* fused all-gather regardless of
    dtype mix (f32 bases, uint32 key stacks, bool init flags, int32 d)."""
    if leaf.dtype == jnp.bool_:
        flat = leaf.astype(jnp.int32)
    else:
        assert leaf.dtype.itemsize == 4, leaf.dtype
        flat = jax.lax.bitcast_convert_type(leaf, jnp.int32)
    return flat.reshape(flat.shape[0], -1)


def _from_i32(col: jnp.ndarray, dtype, shape) -> jnp.ndarray:
    if jnp.dtype(dtype) == jnp.bool_:
        return (col != 0).reshape(shape)
    return jax.lax.bitcast_convert_type(
        col.reshape(shape).astype(jnp.int32), jnp.dtype(dtype))


def _build_sharded_chunk(arch, lr: float, server_lr: float, codecs,
                         dl_codecs, group_paths, rspecs, seed: int,
                         n_clients: int, n_sel: int, c_pad: int):
    """The same chunk as ``_build_chunk``, under ``shard_map`` -- the scan
    runs *inside* the shard_map body, so per-round cross-shard traffic is
    still exactly **two collectives** (on an oversubscribed CPU mesh every
    collective is a lockstep barrier, so per-group/per-leaf collectives
    dominated the round until they were fused):

      * one ``psum`` of the concatenated mask-weighted reconstruction sums
        (compressed groups' recon wire + raw groups' dense deltas, all f32);
      * one ``all_gather`` of the concatenated per-client int32 row
        [per-group stats | bitcast codec-state update] (row order is the
        padded selection order, which every shard holds replicated), sliced
        back to the real (unpadded) clients so ``reduce_stats`` sees
        *exactly* the rows the single-device program reduces -- packed
        stats, and therefore ledger bytes, are identical by construction.
        The gathered state columns scatter into the replicated store
        (padding lanes mirror client ``sel[0]`` and scatter its identical
        update, so duplicates are benign).

    Each shard derives the round's full selection in-jit from the folded
    key chain (replicated arithmetic), pads it to ``c_pad`` with a mirror
    of ``sel[0]``, and slices its local lane block -- matching the padded
    host batch layout by construction.  Everything after the collectives
    (shared-state update incl. in-jit Formula 13, downlink codec, server
    step) is computed redundantly-replicated on every shard, keeping all
    scan carries ``P()``.
    """
    local_train = make_local_train(arch, lr)
    mesh = rspecs.mesh
    ax = rspecs.client_axis_name
    n_shards = rspecs.n_shards
    c_loc = c_pad // n_shards

    def shard_index():
        if isinstance(ax, tuple):
            i = jnp.zeros((), jnp.int32)
            for a in ax:
                i = i * jax.lax.psum(1, a) + jax.lax.axis_index(a)
            return i
        return jax.lax.axis_index(ax)

    def round_body(carry, xs):
        params, cstate, shared, dl_state, dl_shared = carry
        batches, rnd = xs                     # batches: {k: (C_loc, ...)}
        base_key = round_base_key(seed, rnd)
        sel_full = select_round_clients(seed, rnd, n_clients, n_sel)
        if c_pad > n_sel:
            sel_full = jnp.concatenate(
                [sel_full,
                 jnp.broadcast_to(sel_full[0], (c_pad - n_sel,))])
        mask_full = (jnp.arange(c_pad) < n_sel).astype(jnp.float32)
        off0 = shard_index() * c_loc
        sel = jax.lax.dynamic_slice(sel_full, (off0,), (c_loc,))
        mask = jax.lax.dynamic_slice(mask_full, (off0,), (c_loc,))

        def cmask(x):          # (C_loc,) mask broadcast against x's rank
            return mask.reshape(mask.shape + (1,) * (x.ndim - 1))

        locals_ = jax.vmap(local_train, in_axes=(None, 0))(params, batches)
        flat_g = _flatten_groups(params, group_paths)
        flat_l = _flatten_groups(locals_, group_paths)

        # ---- per-shard phase: encode local clients, stage collective rows
        sums = {}                       # path -> local masked sum (wire/raw)
        int_cols = []
        state_cols: Dict[str, list] = {}
        state_meta: Dict[str, tuple] = {}
        stats_of: Dict[str, jnp.ndarray] = {}
        for path in group_paths:
            delta = flat_l[path] - flat_g[path][None]          # (C_loc, ...)
            codec = codecs.get(path)
            if codec is None:
                sums[path] = jnp.sum(delta * cmask(delta), 0)
                continue
            wire = jax.vmap(codec.to_wire)(delta)
            ckeys = jax.vmap(
                lambda c, _co=codec: _co.per_client_key(base_key, c)
            )(sel)
            cst = jax.tree.map(lambda x: x[sel], cstate[path])
            cst2, recon, stats = jax.vmap(
                codec.encode, in_axes=(0, None, 0, 0)
            )(cst, shared[path], ckeys, wire)
            sums[path] = jnp.sum(recon * cmask(recon), 0)
            int_cols.append(stats)
            leaves, treedef = jax.tree.flatten(cst2)
            state_cols[path] = [_as_i32(lf) for lf in leaves]
            state_meta[path] = (treedef, [lf.shape for lf in leaves],
                                [lf.dtype for lf in leaves])

        # ---- collective 1: fused psum of every group's masked sum --------
        flat_sums = jnp.concatenate(
            [sums[p].reshape(-1).astype(jnp.float32) for p in group_paths])
        flat_sums = jax.lax.psum(flat_sums, ax)
        mean_of: Dict[str, jnp.ndarray] = {}
        off = 0
        for path in group_paths:
            size = int(np.prod(sums[path].shape))
            mean_of[path] = (flat_sums[off: off + size]
                             .reshape(sums[path].shape) / n_sel)
            off += size

        # ---- collective 2: fused all-gather of [stats | state] rows ------
        # (row i belongs to padded-selection lane i == client sel_full[i],
        # which every shard already holds replicated -- no id column
        # travels.  Raw-only methods have no rows at all and skip the
        # collective entirely.)
        for path in state_cols:
            int_cols.extend(state_cols[path])
        if int_cols:
            gathered = jax.lax.all_gather(
                jnp.concatenate(int_cols, axis=1), ax, axis=0, tiled=True)
        else:
            gathered = jnp.zeros((c_pad, 0), jnp.int32)
        sel_all = sel_full
        off = 0
        for path in group_paths:
            codec = codecs.get(path)
            if codec is None:
                continue
            stats_of[path] = gathered[:n_sel, off: off + codec.client_stats_len]
            off += codec.client_stats_len
        new_cstate = dict(cstate)
        for path, (treedef, shapes, dtypes) in state_meta.items():
            upd = []
            for shape, dtype in zip(shapes, dtypes):
                size = int(np.prod(shape[1:], dtype=np.int64))
                col = gathered[:, off: off + size]
                upd.append(_from_i32(col, dtype,
                                     (gathered.shape[0],) + shape[1:]))
                off += size
            new_cstate[path] = jax.tree.map(
                lambda x, u: x.at[sel_all].set(u),
                cstate[path], jax.tree.unflatten(treedef, upd))

        # ---- replicated phase: identical on every shard ------------------
        new_shared = dict(shared)
        recon_mean: Dict[str, jnp.ndarray] = {}
        reds: Dict[str, jnp.ndarray] = {}
        for path in group_paths:
            codec = codecs.get(path)
            if codec is None:
                recon_mean[path] = mean_of[path]
                continue
            red = codec.reduce_stats(stats_of[path])
            new_shared[path] = codec.update_shared(shared[path], red,
                                                   mean_of[path])
            recon_mean[path] = codec.from_wire(
                mean_of[path], flat_g[path].shape).astype(flat_g[path].dtype)
            reds[path] = red

        avg = {p: recon_mean[p] * server_lr for p in group_paths}
        new_dl_state, new_dl_shared, dl_reds = _apply_downlink(
            dl_codecs, dl_state, dl_shared, avg, base_key)
        new_flat = {p: flat_g[p] + avg[p].astype(flat_g[p].dtype)
                    for p in group_paths}
        new_params = _set_groups(params, new_flat)
        packed = pack_round_stats(reds, dl_reds)
        return (new_params, new_cstate, new_shared, new_dl_state,
                new_dl_shared), packed

    def core(params, cstate, shared, dl_state, dl_shared, batches,
             round_ids):
        carry, packed = jax.lax.scan(
            round_body, (params, cstate, shared, dl_state, dl_shared),
            (batches, round_ids))
        return carry + (packed,)

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4))
    def chunk_fn(params, cstate, shared, dl_state, dl_shared, batches,
                 round_ids):
        smapped = shard_map(
            core, mesh=mesh,
            in_specs=(P(), P(), P(), P(), P(),
                      rspecs.batch_chunk(batches), P()),
            out_specs=(P(), P(), P(), P(), P(), P()),
            check_rep=False,
        )
        return smapped(params, cstate, shared, dl_state, dl_shared, batches,
                       round_ids)

    return chunk_fn


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_fl_fused(cfg: FLConfig,
                 progress: Optional[Callable[[int, dict], None]] = None) -> FLResult:
    t0 = time.time()
    su = _setup_run(cfg)
    arch, params, policy = su.arch, su.params, su.policy
    eval_fn, eval_block = su.eval_fn, su.eval_block
    ledger, group_paths, n_sel = su.ledger, su.group_paths, su.n_sel

    use_pallas = (jax.default_backend() == "tpu"
                  if cfg.use_pallas is None else cfg.use_pallas)
    C = cfg.n_clients
    ndev = int(cfg.devices or 1)
    K = max(1, int(cfg.scan_rounds))

    codecs = build_codecs(su.method, policy, group_paths, use_pallas, None)
    dl_codecs = (build_downlink_codecs(policy, group_paths, cfg.seed,
                                       use_pallas, None)
                 if cfg.downlink_compress else {})
    acct = RoundAccountant(codecs, dl_codecs, policy, group_paths, n_sel,
                           downlink_enabled=cfg.downlink_compress)

    cstate = {p: c.init_client_state(C) for p, c in codecs.items()}
    shared = {p: c.init_shared_state() for p, c in codecs.items()}
    dl_state = {
        p: jax.tree.map(lambda x: x[0],
                        c.init_client_state(1, client_ids=[SERVER_CLIENT_ID]))
        for p, c in dl_codecs.items()
    }
    dl_shared = {p: c.init_shared_state() for p, c in dl_codecs.items()}

    c_pad = n_sel
    if ndev > 1:
        from repro.launch.mesh import make_fl_mesh
        from repro.launch.sharding import FLRoundSpecs, make_plan

        mesh = make_fl_mesh(ndev)
        rspecs = FLRoundSpecs(make_plan(mesh, arch))
        c_pad = rspecs.pad_clients(n_sel)
        # Commit everything replicated up front so donated buffers alias
        # across chunks instead of being re-laid-out on first use.
        params = rspecs.put_replicated(params)
        cstate = rspecs.put_replicated(cstate)
        shared = rspecs.put_replicated(shared)
        dl_state = rspecs.put_replicated(dl_state)
        dl_shared = rspecs.put_replicated(dl_shared)
        chunk_fn = _build_sharded_chunk(arch, cfg.lr, cfg.server_lr, codecs,
                                        dl_codecs, group_paths, rspecs,
                                        cfg.seed, C, n_sel, c_pad)

        def place(block):
            return rspecs.put_batch_chunk(block)
    else:
        chunk_fn = _build_chunk(arch, cfg.lr, cfg.server_lr, codecs,
                                dl_codecs, group_paths, cfg.seed, C, n_sel)

        def place(block):
            return {k: jnp.asarray(v) for k, v in block.items()}

    # The whole run's selections in one device computation: a pure function
    # of (seed, round) -- the scan body re-derives the identical chain
    # in-jit, the host only needs it to assemble matching batch blocks.
    sel_table = np.asarray(jax.vmap(
        lambda r: select_round_clients(cfg.seed, r, C, n_sel)
    )(jnp.arange(cfg.rounds)))

    def assemble(start: int, end: int):
        """Host side of a chunk: the stacked (Kc, C_pad, steps, B, S) batch
        block, drawn per round / per selected client in the same order as
        the reference loop (padding lanes replicate the round's first
        selected client -- the in-jit mirror of ``sel[0]``).

        Fills one preallocated block per key instead of stacking
        K*C_sel*steps small arrays: for the cheap codecs the round is
        host-bound, and this assembler (plus the stream draw behind it) is
        the host critical path that the K-round scan cannot amortize --
        see the stream-side half of the fix in ``data/synthetic.py``."""
        kc = end - start
        block: Dict[str, np.ndarray] = {}
        for i, r in enumerate(range(start, end)):
            for j, c in enumerate(sel_table[r]):
                stream = su.streams[int(c)]
                for s in range(cfg.local_steps):
                    b = next(stream)
                    if not block:
                        block = {
                            kk: np.empty(
                                (kc, c_pad, cfg.local_steps) + np.shape(v),
                                np.asarray(v).dtype)
                            for kk, v in b.items()}
                    for kk, v in b.items():
                        block[kk][i, j, s] = v
        if c_pad > n_sel:
            for v in block.values():
                v[:, n_sel:] = v[:, :1]
        return place(block)

    chunks = plan_chunks(cfg.rounds, cfg.eval_every, K)
    res = FLResult([], [], [], [], ledger, 0.0)
    round_wall = []
    chunk_spans = []        # (perf_counter start, end) per chunk dispatch
    pending = None          # (stacked packed stats device array, start, end)

    def drain():
        nonlocal pending
        if pending is not None:
            rows = host_fetch(pending[0])          # one fetch per chunk
            for i, r in enumerate(range(pending[1], pending[2])):
                acct.consume(rows[i], ledger, r)
            pending = None

    for start, end in chunks:
        t_chunk = time.perf_counter()
        for _ in range(start, end):
            ledger.begin_round()
        batches = assemble(start, end)
        # host numpy, not jnp.arange: an eager jnp.arange bakes (start, end)
        # as constants and would compile a fresh tiny executable per chunk.
        round_ids = np.arange(start, end, dtype=np.int32)
        out = chunk_fn(params, cstate, shared, dl_state, dl_shared, batches,
                       round_ids)
        params, cstate, shared, dl_state, dl_shared, packed = out
        # Consume the *previous* chunk's stats only after this chunk is
        # dispatched: the fetch (and the accounting behind it) overlaps
        # this chunk's device compute.
        drain()
        pending = (packed, start, end)
        if hasattr(packed, "copy_to_host_async"):
            packed.copy_to_host_async()
        dt = time.perf_counter() - t_chunk
        chunk_spans.append((t_chunk, t_chunk + dt))
        round_wall += [dt / (end - start)] * (end - start)

        rnd = end - 1
        if rnd % cfg.eval_every == 0 or rnd == cfg.rounds - 1:
            drain()                       # ledger exact before reporting
            la = host_fetch(eval_fn(params, eval_block))
            res.eval_rounds.append(rnd)
            res.eval_loss.append(float(la[0]))
            res.eval_acc.append(float(la[1]))
            res.uplink_bytes.append(ledger.uplink_total)
            if progress:
                progress(rnd, {"loss": res.eval_loss[-1],
                               "acc": res.eval_acc[-1],
                               "uplink": ledger.uplink_total})
    drain()

    res.wall_s = time.time() - t0
    res.extra["engine"] = "fused"
    res.extra["use_pallas"] = use_pallas
    res.extra["round_wall_s"] = round_wall
    res.extra["devices"] = ndev
    res.extra["scan_rounds"] = K
    res.extra["chunks"] = len(chunks)
    res.extra["chunk_spans"] = chunk_spans
    res.extra["chunk_shapes"] = len({e - s for s, e in chunks})
    # One executable per distinct chunk length == zero mid-run recompiles;
    # asserted by tests and the CI recompile guard.
    try:
        res.extra["chunk_compiles"] = int(chunk_fn._cache_size())
    except Exception:
        res.extra["chunk_compiles"] = -1
    res.extra.update(acct.metrics)
    return res
