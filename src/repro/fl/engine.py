"""Fused client-parallel FL round engine (DESIGN.md Sec. 8).

One FL round == one jitted XLA program, for **every** uplink method:

  * local training is ``vmap``-ed over the selected-client axis (the exact
    ``make_local_train`` step the reference loop uses, so per-client math is
    unchanged);
  * compression is method-generic: each parameter group's
    :class:`repro.core.codecs.Codec` is vmapped over the client axis --
    GradESTC's stacked ``(C, L, l, k)`` bases, the per-tensor baselines'
    stacked ``(C, n)`` flat vectors, SVDFed's shared server basis -- so one
    ``vmap(codec.encode)`` covers all selected clients per group;
  * reconstruction, client averaging, the optional in-jit **downlink codec**
    (the shared server-side GradESTC compressor), and the server parameter
    update all happen inside the same program;
  * exactly **one** device->host transfer leaves the program per round: the
    packed int32 stats vector (per-group codec stats, uplink and downlink),
    which :class:`repro.fl.compression.RoundAccountant` -- shared verbatim
    with the reference loop -- turns into exact integer-bit ledger charges
    and the next round's static codec config (Formula 13).

Static per-round config (GradESTC's rSVD candidate count ``d``) travels as
hashable ``(path, static)`` tuples, so the engine retraces only when
Formula 13 actually moves a group to a new power-of-two bucket -- the same
bounded-recompilation contract as the reference loop.

The per-client Python loop (``simulation._run_fl_loop``) stays as the parity
oracle; ``tests/test_round_engine.py`` pins the two engines to each other
for all seven methods.
"""

from __future__ import annotations

import functools
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codecs import SERVER_CLIENT_ID
from repro.core.metrics import host_fetch

from .compression import (
    RoundAccountant,
    build_codecs,
    build_downlink_codecs,
    pack_round_stats,
    round_base_key,
)
from .simulation import (
    FLConfig,
    FLResult,
    _flatten_groups,
    _set_groups,
    _setup_run,
    make_local_train,
)

__all__ = ["run_fl_fused"]


def _build_round(arch, lr: float, server_lr: float, codecs, dl_codecs,
                 group_paths):
    """Returns a jitted ``round_fn`` generic over the codec dicts.

    ``static_map`` / ``dl_static_map`` are hashable ``(path, static)``
    tuples -- the only static inputs that change across rounds (bucketed
    powers of two for GradESTC's ``d``; ``None`` for static-free codecs).
    ``mode`` / ``dl_mode`` statically select the init/update branch
    structure for codecs with an init branch (see ``GradESTCCodec``).
    """
    local_train = make_local_train(arch, lr)

    @functools.partial(jax.jit, static_argnames=(
        "static_map", "dl_static_map", "mode", "dl_mode", "full_part"))
    def round_fn(params, cstate, shared, dl_state, batches, sel, base_key,
                 static_map, dl_static_map, mode, dl_mode, full_part):
        static_of = dict(static_map)
        dl_static_of = dict(dl_static_map)

        def take(x):
            return x if full_part else x[sel]

        def put(x, upd):
            return upd if full_part else x.at[sel].set(upd)

        locals_ = jax.vmap(local_train, in_axes=(None, 0))(params, batches)
        flat_g = _flatten_groups(params, group_paths)
        flat_l = _flatten_groups(locals_, group_paths)

        new_cstate, new_shared = dict(cstate), dict(shared)
        new_dl_state = dict(dl_state)
        recon_mean: Dict[str, jnp.ndarray] = {}
        reds: Dict[str, jnp.ndarray] = {}
        for path in group_paths:
            delta = flat_l[path] - flat_g[path][None]          # (C_sel, ...)
            codec = codecs.get(path)
            if codec is None:
                recon_mean[path] = jnp.sum(delta, 0) / delta.shape[0]
                continue
            wire = jax.vmap(codec.to_wire)(delta)
            ckeys = jax.vmap(
                lambda c, _co=codec: _co.per_client_key(base_key, c)
            )(sel)
            enc = functools.partial(codec.encode,
                                    static=static_of.get(path), mode=mode)
            cst = jax.tree.map(take, cstate[path])
            cst2, recon, stats = jax.vmap(enc, in_axes=(0, None, 0, 0))(
                cst, shared[path], ckeys, wire
            )
            new_cstate[path] = jax.tree.map(put, cstate[path], cst2)
            red = codec.reduce_stats(stats)
            mean_wire = jnp.sum(recon, 0) / delta.shape[0]
            new_shared[path] = codec.update_shared(shared[path], red,
                                                   mean_wire)
            recon_mean[path] = codec.from_wire(
                mean_wire, flat_g[path].shape).astype(delta.dtype)
            reds[path] = red

        avg = {p: recon_mean[p] * server_lr for p in group_paths}

        # Optional downlink codec: the server compresses the aggregated
        # update once; every client mirrors the shared decompressor, so the
        # server applies the *reconstruction* to stay bit-identical with
        # clients -- all in-jit, its stats ride the same packed transfer.
        dl_reds: Dict[str, jnp.ndarray] = {}
        for path in group_paths:
            dlc = dl_codecs.get(path)
            if dlc is None:
                continue
            wire = dlc.to_wire(avg[path])
            cst2, recon_w, stats = dlc.encode(
                dl_state[path], (), base_key, wire,
                static=dl_static_of.get(path), mode=dl_mode,
            )
            new_dl_state[path] = cst2
            avg[path] = dlc.from_wire(
                recon_w, avg[path].shape).astype(avg[path].dtype)
            dl_reds[path] = dlc.reduce_stats(stats[None])

        new_flat = {p: flat_g[p] + avg[p].astype(flat_g[p].dtype)
                    for p in group_paths}
        new_params = _set_groups(params, new_flat)
        packed = pack_round_stats(reds, dl_reds)
        return new_params, new_cstate, new_shared, new_dl_state, packed

    return round_fn


def run_fl_fused(cfg: FLConfig,
                 progress: Optional[Callable[[int, dict], None]] = None) -> FLResult:
    t0 = time.time()
    su = _setup_run(cfg)
    arch, params, policy = su.arch, su.params, su.policy
    streams, eval_batches, eval_step = su.streams, su.eval_batches, su.eval_step
    ledger, rng, group_paths, n_sel = su.ledger, su.rng, su.group_paths, su.n_sel

    use_pallas = (jax.default_backend() == "tpu"
                  if cfg.use_pallas is None else cfg.use_pallas)
    C = cfg.n_clients

    codecs = build_codecs(su.method, policy, group_paths, use_pallas, None)
    dl_codecs = (build_downlink_codecs(policy, group_paths, cfg.seed,
                                       use_pallas, None)
                 if cfg.downlink_compress else {})
    acct = RoundAccountant(codecs, dl_codecs, policy, group_paths, n_sel,
                           downlink_enabled=cfg.downlink_compress)

    cstate = {p: c.init_client_state(C) for p, c in codecs.items()}
    shared = {p: c.init_shared_state() for p, c in codecs.items()}
    dl_state = {
        p: jax.tree.map(lambda x: x[0],
                        c.init_client_state(1, client_ids=[SERVER_CLIENT_ID]))
        for p, c in dl_codecs.items()
    }

    round_fn = _build_round(arch, cfg.lr, cfg.server_lr, codecs, dl_codecs,
                            group_paths)

    res = FLResult([], [], [], [], ledger, 0.0)
    round_wall = []
    # Host mirror of which clients hold an initialized compressor (a client
    # inits on first selection) -- lets the common rounds compile cond-free.
    has_init = any(c.has_init_branch for c in codecs.values())
    dl_has_init = any(c.has_init_branch for c in dl_codecs.values())
    client_inited = np.zeros(C, bool)

    for rnd in range(cfg.rounds):
        t_round = time.perf_counter()
        ledger.begin_round()
        sel = sorted(rng.choice(C, size=n_sel, replace=False))
        # Assemble the round's (C_sel, steps, B, S) batch block on the host
        # and ship it in one transfer -- not one jnp.stack dispatch per
        # client (the streams yield CPU-backed arrays; np.asarray is cheap).
        per_client = []
        for c in sel:
            bs = [next(streams[c]) for _ in range(cfg.local_steps)]
            per_client.append({kk: np.stack([np.asarray(b[kk]) for b in bs])
                               for kk in bs[0]})
        batches = {kk: jnp.asarray(np.stack([pc[kk] for pc in per_client]))
                   for kk in per_client[0]}
        if has_init:
            sel_inited = client_inited[sel]
            mode = ("update" if sel_inited.all()
                    else "init" if not sel_inited.any() else "mixed")
            client_inited[sel] = True
        else:
            mode = "update"
        dl_mode = "init" if (dl_has_init and rnd == 0) else "update"
        up_map, dl_map = acct.static_args()
        base_key = round_base_key(cfg.seed, rnd)
        params, cstate, shared, dl_state, packed = round_fn(
            params, cstate, shared, dl_state, batches, jnp.asarray(sel),
            base_key, up_map, dl_map, mode, dl_mode, n_sel == C,
        )

        # ---- the single host sync: ledger charge + Formula 13 --------
        acct.consume(host_fetch(packed), ledger, rnd)
        round_wall.append(time.perf_counter() - t_round)

        if rnd % cfg.eval_every == 0 or rnd == cfg.rounds - 1:
            ls, accs = zip(*[eval_step(params, b) for b in eval_batches])
            res.eval_rounds.append(rnd)
            res.eval_loss.append(float(np.mean([float(l) for l in ls])))
            res.eval_acc.append(float(np.mean([float(a) for a in accs])))
            res.uplink_bytes.append(ledger.uplink_total)
            if progress:
                progress(rnd, {"loss": res.eval_loss[-1], "acc": res.eval_acc[-1],
                               "uplink": ledger.uplink_total})

    res.wall_s = time.time() - t0
    res.extra["engine"] = "fused"
    res.extra["use_pallas"] = use_pallas
    res.extra["round_wall_s"] = round_wall
    res.extra.update(acct.metrics)
    return res
