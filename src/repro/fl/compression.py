"""Uplink compression methods as thin shells over the stateless codecs.

A *method* (``make_method``) is host-side configuration only: it knows how
to build one :class:`repro.core.codecs.Codec` per parameter group
(``build_codec``).  All array state -- per-client bases, error memories,
rSVD key chains, the SVDFed shared basis -- lives in explicit codec state
pytrees owned by the round engines, so the same codec runs vmapped over
the client axis inside the fused single-XLA-program round *and* per client
in the reference loop.  (The old ``*Method`` classes kept that state in
Python dicts keyed by ``(client, path)``, which is why only GradESTC could
run fused before.)

:class:`RoundAccountant` is the host half of the protocol, shared by both
engines: it consumes the packed int32 stats vector a round produces (one
row of the K-round stats block the scan engine fetches per chunk) and
charges the ledger in exact integer-bit arithmetic.  There is no host-side
per-round codec config left to advance -- GradESTC's Formula 13 candidate
count is traced shared state updated in-jit, and the ``d`` a round used
travels in its stats row.  Byte parity between the engines is by
construction -- there is exactly one charging code path.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.codecs import (
    Codec, EFCodec, FedPAQCodec, FedQClipCodec, GradESTCCodec,
    SERVER_CLIENT_ID, SignSGDCodec, SVDFedCodec, TopKCodec,
    client_layer_keys, round_base_key,
)
from repro.core.policy import CompressionPolicy, LayerPlan

__all__ = [
    "make_method", "client_layer_keys", "round_base_key", "path_index",
    "build_codecs", "build_downlink_codecs", "pack_round_stats",
    "RoundAccountant",
    "FedAvgMethod", "TopKMethod", "FedPAQMethod", "SignSGDMethod",
    "FedQClipMethod", "SVDFedMethod", "GradESTCMethod",
]


def path_index(policy: CompressionPolicy) -> Dict[str, int]:
    """Stable group-name -> int map (sorted order) for PRNG key derivation."""
    return {name: i for i, name in enumerate(sorted(policy.plans))}


class _MethodShell:
    """Host-side method config.  ``build_codec`` returns the codec for one
    parameter group, or ``None`` when that group ships raw."""

    name = "?"

    def __init__(self, seed: int = 0, **_):
        self.seed = seed

    def build_codec(self, path: str, plan: LayerPlan, path_idx: int,
                    use_pallas: bool = False,
                    pallas_interpret: Optional[bool] = None) -> Optional[Codec]:
        raise NotImplementedError


class FedAvgMethod(_MethodShell):
    """Uncompressed reference: every group ships raw."""

    name = "fedavg"

    def build_codec(self, path, plan, path_idx, use_pallas=False,
                    pallas_interpret=None):
        return None


class TopKMethod(_MethodShell):
    """Per-tensor magnitude top-k with error memory (ref [23])."""

    name = "topk"

    def __init__(self, frac: float = 0.1, **kw):
        super().__init__(**kw)
        self.frac = frac

    def build_codec(self, path, plan, path_idx, use_pallas=False,
                    pallas_interpret=None):
        return TopKCodec(plan.raw_scalars, frac=self.frac, path_idx=path_idx)


class FedPAQMethod(_MethodShell):
    """Stochastic uniform quantization of every tensor (ref [21])."""

    name = "fedpaq"

    def __init__(self, bits: int = 8, **kw):
        super().__init__(**kw)
        self.bits = bits

    def build_codec(self, path, plan, path_idx, use_pallas=False,
                    pallas_interpret=None):
        return FedPAQCodec(plan.raw_scalars, bits=self.bits, path_idx=path_idx,
                           use_pallas=use_pallas,
                           pallas_interpret=pallas_interpret)


class SignSGDMethod(_MethodShell):
    name = "signsgd"

    def build_codec(self, path, plan, path_idx, use_pallas=False,
                    pallas_interpret=None):
        return SignSGDCodec(plan.raw_scalars, path_idx=path_idx,
                            use_pallas=use_pallas,
                            pallas_interpret=pallas_interpret)


class FedQClipMethod(_MethodShell):
    """Clipped + quantized updates (ref [42])."""

    name = "fedqclip"

    def __init__(self, clip: float = 100.0, bits: int = 8, **kw):
        super().__init__(**kw)
        self.clip = clip
        self.bits = bits

    def build_codec(self, path, plan, path_idx, use_pallas=False,
                    pallas_interpret=None):
        return FedQClipCodec(plan.raw_scalars, clip=self.clip, bits=self.bits,
                             path_idx=path_idx, use_pallas=use_pallas,
                             pallas_interpret=pallas_interpret)


class SVDFedMethod(_MethodShell):
    """Shared server-fit basis, coefficient uplink between refits (ref [12])."""

    name = "svdfed"

    def __init__(self, policy: CompressionPolicy, gamma: float = 8.0,
                 wire_dtype: str = "f32", **kw):
        super().__init__(**kw)
        self.policy = policy
        self.gamma = gamma
        # explicit (not **kw): _MethodShell swallows unknown kwargs, and a
        # silently dropped wire_dtype would charge f32 bits for an f32 wire
        # the caller believed was int8.
        self.wire_dtype = wire_dtype

    def build_codec(self, path, plan, path_idx, use_pallas=False,
                    pallas_interpret=None):
        if not plan.compress:
            return None
        return SVDFedCodec(plan, gamma=self.gamma, seed=self.seed,
                           path_idx=path_idx, use_pallas=use_pallas,
                           pallas_interpret=pallas_interpret,
                           wire_dtype=self.wire_dtype)


class GradESTCMethod(_MethodShell):
    """The paper's method.  variant in {"full", "first", "all", "k"}
    (Table IV ablations); ``ef`` enables error feedback (beyond-paper)."""

    name = "gradestc"

    def __init__(self, policy: CompressionPolicy, variant: str = "full",
                 alpha: float = 1.3, beta: float = 1.0, ef: bool = False,
                 wire_dtype: str = "f32", **kw):
        assert variant in ("full", "first", "all", "k")
        super().__init__(**kw)
        self.policy = policy
        self.variant = variant
        self.alpha, self.beta = alpha, beta
        self.ef = ef
        # explicit (not **kw) for the same reason as SVDFedMethod
        self.wire_dtype = wire_dtype

    def build_codec(self, path, plan, path_idx, use_pallas=False,
                    pallas_interpret=None):
        if not plan.compress:
            return None
        codec = GradESTCCodec(plan, seed=self.seed, path_idx=path_idx,
                              variant=self.variant, alpha=self.alpha,
                              beta=self.beta, use_pallas=use_pallas,
                              pallas_interpret=pallas_interpret,
                              wire_dtype=self.wire_dtype)
        if self.ef:
            codec = EFCodec(codec, (plan.stack, plan.l, plan.m))
        return codec


def make_method(name: str, policy: Optional[CompressionPolicy] = None, **kw):
    name = name.lower()
    if name == "fedavg":
        return FedAvgMethod(**kw)
    if name == "topk":
        return TopKMethod(**kw)
    if name == "fedpaq":
        return FedPAQMethod(**kw)
    if name == "signsgd":
        return SignSGDMethod(**kw)
    if name == "fedqclip":
        return FedQClipMethod(**kw)
    if name == "svdfed":
        assert policy is not None
        return SVDFedMethod(policy, **kw)
    if name.startswith("gradestc"):
        assert policy is not None
        variant = "full"
        ef = False
        if "-" in name:
            suffix = name.split("-", 1)[1]
            if suffix == "ef":
                ef = True
            else:
                variant = suffix
        return GradESTCMethod(policy, variant=variant, ef=ef, **kw)
    raise ValueError(f"unknown method {name!r}")


def build_codecs(method, policy: CompressionPolicy, group_paths,
                 use_pallas: bool = False,
                 pallas_interpret: Optional[bool] = None) -> Dict[str, Codec]:
    """One codec per compressed group; paths absent from the result ship raw."""
    pidx = path_index(policy)
    out: Dict[str, Codec] = {}
    for path in group_paths:
        codec = method.build_codec(path, policy.plans[path], pidx[path],
                                   use_pallas, pallas_interpret)
        if codec is not None:
            out[path] = codec
    return out


def build_downlink_codecs(policy: CompressionPolicy, group_paths, seed: int,
                          use_pallas: bool = False,
                          pallas_interpret: Optional[bool] = None,
                          ) -> Dict[str, Codec]:
    """The shared server-side GradESTC codec compressing the broadcast
    (``FLConfig.downlink_compress``); one 'client' with id
    ``SERVER_CLIENT_ID``, seeded independently of the uplink codecs."""
    method = make_method("gradestc", policy=policy, seed=seed + 101)
    return build_codecs(method, policy, group_paths, use_pallas,
                        pallas_interpret)


def pack_round_stats(reds: Dict[str, jnp.ndarray],
                     dl_reds: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """The round's packed stats vector: reduced int32 stats per sorted
    uplink path, then per sorted downlink path.  Both engines build the
    transfer through this one function so the layout
    ``RoundAccountant.consume`` unpacks cannot drift between them.
    Stats-free rounds still ship a one-element placeholder -- the single
    measured host sync stays uniform across methods."""
    parts = ([reds[p] for p in sorted(reds)]
             + [dl_reds[p] for p in sorted(dl_reds)])
    if parts and sum(int(p.size) for p in parts):
        return jnp.concatenate(parts)
    return jnp.zeros((1,), jnp.int32)


class RoundAccountant:
    """Host half of the codec protocol, shared verbatim by both engines.

    Consumes one round's packed int32 stats row (rows of the single
    measured per-chunk ``host_fetch`` in the scan engine; one fetch per
    round in the reference loop), charges uplink/downlink in exact integer
    bits (``CommLedger.charge_uplink_bits``), and merges host metrics
    (``sum_d``).  Pure per-row: it carries no per-round state, so rows may
    be consumed late (the engine defers a chunk's fetch one chunk) as long
    as ``round_idx`` pins each charge to its slot.
    """

    def __init__(self, codecs: Dict[str, Codec], dl_codecs: Dict[str, Codec],
                 policy: CompressionPolicy, group_paths, n_sel: int,
                 downlink_enabled: bool = False):
        self.codecs = {p: codecs[p] for p in sorted(codecs)}
        self.dl_codecs = {p: dl_codecs[p] for p in sorted(dl_codecs)}
        self.n_sel = n_sel
        self.downlink_enabled = downlink_enabled
        self.metrics: Dict[str, int] = {}
        self.raw_scalars_per_client = sum(
            policy.plans[p].raw_scalars for p in group_paths if p not in codecs
        )
        self.model_scalars = sum(
            policy.plans[p].raw_scalars for p in group_paths
        )
        self.dl_raw_scalars = sum(
            policy.plans[p].raw_scalars for p in group_paths
            if p not in dl_codecs
        )
        self.packed_len = (sum(c.stats_len for c in self.codecs.values())
                           + sum(c.stats_len for c in self.dl_codecs.values()))

    def consume(self, packed: np.ndarray, ledger, rnd: int) -> None:
        """Charge the ledger for round ``rnd`` from its fetched stats row."""
        packed = np.asarray(packed).reshape(-1)
        expected = max(self.packed_len, 1)    # pack_round_stats placeholder
        if packed.size != expected:
            raise ValueError(
                f"packed stats layout drift: got {packed.size} entries, "
                f"expected {expected} -- engine packing disagrees with the "
                f"registered codecs")
        off = 0
        bits = 32 * self.raw_scalars_per_client * self.n_sel
        for path, codec in self.codecs.items():
            red = packed[off: off + codec.stats_len]
            off += codec.stats_len
            bits += codec.charge_bits(red, self.n_sel)
            for k, v in codec.host_metrics(red, self.n_sel).items():
                self.metrics[k] = self.metrics.get(k, 0) + v
        # round_idx pins the charge to round ``rnd``'s ledger slot: the
        # chunked engine has usually begun the next chunk by the time round
        # rnd's stats arrive.
        ledger.charge_uplink_bits(bits, group=f"round{rnd}", round_idx=rnd)

        if self.downlink_enabled:
            dbits = 32 * self.dl_raw_scalars
            for path, codec in self.dl_codecs.items():
                red = packed[off: off + codec.stats_len]
                off += codec.stats_len
                dbits += codec.charge_bits(red, 1)
            ledger.charge_downlink_bits(dbits * self.n_sel)
        else:
            ledger.charge_downlink_bits(32 * self.model_scalars * self.n_sel)
