"""Uplink compression methods over model-update pytrees.

Bridges ``core/`` (which works on single (l, m) matrices) to whole-model
updates: each method consumes ``{group_path: delta_array}`` for one client
and returns the server-side reconstruction plus exact transmitted scalars.

GradESTC state is vmapped over the stacked layer axis of each parameter
group (one compressor-decompressor pair per layer per group, exactly the
paper's "each client has multiple compressors" -- Sec. III).  The dynamic
candidate count ``d`` is adjusted on the host per group (Formula 13) and
bucketed to powers of two to bound recompilation (DESIGN.md).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as bl
from repro.core import gradestc as ge
from repro.core.error_feedback import EFState, ef_inject, ef_update
from repro.core.metrics import host_fetch
from repro.core.policy import CompressionPolicy, LayerPlan
from repro.core.reshaping import matrix_to_tensor, reshape_to_matrix

__all__ = [
    "make_method", "client_layer_keys", "path_index",
    "FedAvgMethod", "TopKMethod", "FedPAQMethod", "SignSGDMethod",
    "FedQClipMethod", "SVDFedMethod", "GradESTCMethod",
]

Deltas = Dict[str, jnp.ndarray]


def _tree_scalars(deltas: Deltas) -> float:
    return float(sum(np.prod(v.shape) for v in deltas.values()))


class FedAvgMethod:
    """Uncompressed reference."""

    name = "fedavg"

    def __init__(self, **_):
        pass

    def round_payload(self, client: int, deltas: Deltas, key, rnd: int):
        return deltas, _tree_scalars(deltas)


@functools.partial(jax.jit, static_argnames=("k",))
def _topk_flat(mem, flat, k: int):
    st, ghat, sc = bl.topk_compress(bl.TopKState(mem), flat, k)
    return st.memory, ghat, sc


class TopKMethod:
    """Per-tensor magnitude top-k with error memory (ref [23])."""

    name = "topk"

    def __init__(self, frac: float = 0.1, **_):
        self.frac = frac
        self.mem: Dict[Tuple[int, str], jnp.ndarray] = {}

    def round_payload(self, client: int, deltas: Deltas, key, rnd: int):
        recon, scalars = {}, 0.0
        for path, v in deltas.items():
            flat = v.reshape(-1)
            k = max(1, int(self.frac * flat.size))
            mem = self.mem.get((client, path), jnp.zeros_like(flat))
            mem, ghat, sc = _topk_flat(mem, flat, k)
            self.mem[(client, path)] = mem
            recon[path] = ghat.reshape(v.shape)
            scalars += float(sc)
        return recon, scalars


class FedPAQMethod:
    """Stochastic 8-bit quantization of every tensor (ref [21])."""

    name = "fedpaq"

    def __init__(self, bits: int = 8, **_):
        self.bits = bits

    def round_payload(self, client: int, deltas: Deltas, key, rnd: int):
        recon, scalars = {}, 0.0
        keys = jax.random.split(key, len(deltas))
        for kk, (path, v) in zip(keys, sorted(deltas.items())):
            _, ghat, sc = bl.fedpaq_compress(bl.QuantState(), v.reshape(-1), kk, self.bits)
            recon[path] = ghat.reshape(v.shape).astype(v.dtype)
            scalars += float(sc)
        return recon, scalars


class SignSGDMethod:
    name = "signsgd"

    def __init__(self, **_):
        pass

    def round_payload(self, client: int, deltas: Deltas, key, rnd: int):
        recon, scalars = {}, 0.0
        for path, v in deltas.items():
            ghat, sc = bl.sign_compress(v.reshape(-1))
            recon[path] = ghat.reshape(v.shape).astype(v.dtype)
            scalars += float(sc)
        return recon, scalars


class FedQClipMethod:
    """Clipped + quantized updates (ref [42])."""

    name = "fedqclip"

    def __init__(self, clip: float = 100.0, bits: int = 8, **_):
        self.clip = clip
        self.bits = bits

    def round_payload(self, client: int, deltas: Deltas, key, rnd: int):
        recon, scalars = {}, 0.0
        keys = jax.random.split(key, len(deltas))
        for kk, (path, v) in zip(keys, sorted(deltas.items())):
            ghat, sc = bl.fedqclip_compress(v.reshape(-1), kk, self.clip, self.bits)
            recon[path] = ghat.reshape(v.shape).astype(v.dtype)
            scalars += float(sc)
        return recon, scalars


# --------------------------------------------------------------------------
# SVDFed: globally shared per-group basis (ref [12])
# --------------------------------------------------------------------------

@dataclass
class _SVDFedGroup:
    M: Optional[jnp.ndarray] = None       # (L, l, k) shared basis
    want_refresh: bool = True
    pending: list = field(default_factory=list)   # G matrices this round


class SVDFedMethod:
    """Shared basis fit by the server from aggregated gradients; clients
    upload coefficients between refits.  A refit round costs full uplink
    (clients ship raw G so the server can re-fit), matching SVDFed's
    calibration rounds."""

    name = "svdfed"

    def __init__(self, policy: CompressionPolicy, gamma: float = 8.0, seed: int = 0, **_):
        self.policy = policy
        self.gamma = gamma
        self.groups: Dict[str, _SVDFedGroup] = {}
        self.key = jax.random.PRNGKey(seed + 17)

    def round_payload(self, client: int, deltas: Deltas, key, rnd: int):
        recon, scalars = {}, 0.0
        for path, v in deltas.items():
            plan = self.policy.plans.get(path)
            if plan is None or not plan.compress:
                recon[path] = v
                scalars += v.size
                continue
            st = self.groups.setdefault(path, _SVDFedGroup())
            GL = _to_matrices(v, plan)                       # (L, l, m)
            if st.want_refresh or st.M is None:
                st.pending.append(GL)
                recon[path] = v                              # raw uplink
                scalars += v.size
            else:
                A = jnp.einsum("xlk,xlm->xkm", st.M, GL)
                Ghat = jnp.einsum("xlk,xkm->xlm", st.M, A)
                E = GL - Ghat
                rel = float(jnp.sqrt(jnp.sum(E * E) / jnp.maximum(jnp.sum(GL * GL), 1e-30)))
                if rel > self.gamma / 100.0:
                    st.want_refresh = True
                recon[path] = _from_matrices(Ghat, plan, v.shape)
                scalars += plan.k * plan.m * plan.stack
        return recon, scalars

    def end_round(self):
        """Server-side: refit bases queued for refresh."""
        for path, st in self.groups.items():
            if st.pending:
                G_agg = sum(st.pending) / len(st.pending)
                self.key, sub = jax.random.split(self.key)
                plan = self.policy.plans[path]
                U = jax.vmap(
                    lambda g, kk: _rsvd_basis(kk, g, plan.k)
                )(G_agg, jax.random.split(sub, G_agg.shape[0]))
                st.M = U
                st.pending = []
                st.want_refresh = False


@functools.partial(jax.jit, static_argnames=("k",))
def _rsvd_basis(key, G, k: int):
    from repro.core.rsvd import randomized_svd
    U, _, _ = randomized_svd(key, G, rank=k)
    return U


# --------------------------------------------------------------------------
# GradESTC (the paper) + ablation variants
# --------------------------------------------------------------------------

def path_index(policy: CompressionPolicy) -> Dict[str, int]:
    """Stable group-name -> int map (sorted order) for PRNG key derivation."""
    return {name: i for i, name in enumerate(sorted(policy.plans))}


def client_layer_keys(seed: int, client, path_idx, L: int) -> jnp.ndarray:
    """Per-(client, group) rSVD key stack, one key per stacked layer.

    Derived with ``fold_in`` chains only -- NOT Python ``hash()``, whose
    string hashing is salted by ``PYTHONHASHSEED`` and therefore differs
    across processes.  ``client``/``path_idx`` may be traced int32 scalars,
    so the same derivation runs inside the fused engine's jitted round and
    in the host reference loop, producing identical streams.
    """
    if isinstance(client, int):
        client &= 0xFFFFFFFF    # server-side codecs use client=-1
    base = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), client), path_idx
    )
    return jax.random.split(base, L)


def _to_matrices(v: jnp.ndarray, plan: LayerPlan) -> jnp.ndarray:
    """Stacked delta (L, *shape) (or (*shape,) for stack=1) -> (L, l, m)."""
    L = plan.stack
    flat = v.reshape(L, -1)
    m = plan.n // plan.l
    return flat.reshape(L, m, plan.l).swapaxes(-1, -2)   # columns = segments


def _from_matrices(GL: jnp.ndarray, plan: LayerPlan, shape) -> jnp.ndarray:
    L = plan.stack
    flat = GL.swapaxes(-1, -2).reshape(L, plan.n)
    return flat.reshape(shape)


@functools.partial(jax.jit, static_argnames=("k",))
def _ge_init_group(keys, GL, k: int):
    def one(key, G):
        st = ge.CompressorState(M=jnp.zeros((G.shape[0], k), G.dtype), key=key,
                                initialized=jnp.zeros((), jnp.bool_))
        st2, payload, stats = ge.compress_init(st, G, k=k)
        return st2.M, st2.key, ge.reconstruct(st2.M, payload.coeffs), stats.d_r
    M, keys2, Ghat, d_r = jax.vmap(one)(keys, GL)
    return M, keys2, Ghat, d_r


@functools.partial(jax.jit, static_argnames=("k", "d"))
def _ge_update_group(M, keys, GL, k: int, d: int):
    def one(Mi, key, G):
        st = ge.CompressorState(M=Mi, key=key, initialized=jnp.ones((), jnp.bool_))
        st2, payload, stats = ge.compress_update(st, G, k=k, d=d)
        return st2.M, st2.key, ge.reconstruct(st2.M, payload.coeffs), stats.d_r, stats.recon_err
    M2, keys2, Ghat, d_r, err = jax.vmap(one)(M, keys, GL)
    return M2, keys2, Ghat, d_r, err


class GradESTCMethod:
    """The paper's method.  variant in {"full", "first", "all", "k"}
    (Table IV ablations); ``ef`` enables error feedback (beyond-paper)."""

    name = "gradestc"

    def __init__(
        self, policy: CompressionPolicy, variant: str = "full",
        alpha: float = 1.3, beta: float = 1.0, ef: bool = False,
        seed: int = 0, **_,
    ):
        assert variant in ("full", "first", "all", "k")
        self.policy = policy
        self.variant = variant
        self.alpha, self.beta = alpha, beta
        self.ef = ef
        self.seed = seed
        self._path_idx = path_index(policy)
        # per (client, group): basis stack, rng keys, EF memory
        self.M: Dict[Tuple[int, str], jnp.ndarray] = {}
        self.keys: Dict[Tuple[int, str], jnp.ndarray] = {}
        # candidate count d is per *group*, shared by all clients (matching
        # the fused engine's single static d per compiled round); Formula 13
        # re-buckets it at end_round() from the round's max d_r.
        self.d: Dict[str, int] = {}
        self._round_drmax: Dict[str, int] = {}
        self.efmem: Dict[Tuple[int, str], jnp.ndarray] = {}
        self.sum_d = 0          # computational-overhead proxy (Table IV)
        self.last_err: Dict[str, float] = {}

    def _keys_for(self, client: int, path: str, L: int):
        kk = (client, path)
        if kk not in self.keys:
            self.keys[kk] = client_layer_keys(
                self.seed, client, self._path_idx[path], L
            )
        return self.keys[kk]

    def round_payload(self, client: int, deltas: Deltas, key, rnd: int):
        recon, scalars = {}, 0.0
        for path, v in sorted(deltas.items()):
            plan = self.policy.plans.get(path)
            if plan is None or not plan.compress:
                recon[path] = v
                scalars += v.size
                continue
            kk = (client, path)
            GL = _to_matrices(v, plan).astype(jnp.float32)
            L, k = plan.stack, plan.k
            keys = self._keys_for(client, path, L)
            if self.ef:
                mem = self.efmem.get(kk)
                if mem is not None:
                    GL = GL + mem
            first_round = kk not in self.M

            if first_round or self.variant == "all":
                M, keys2, Ghat, d_r = _ge_init_group(keys, GL, k)
                self.M[kk], self.keys[kk] = M, keys2
                scalars += plan.init_scalars
                self.d.setdefault(path, max(1, k // 4))
                self.sum_d += k * L
            elif self.variant == "first":
                M = self.M[kk]
                A = jnp.einsum("xlk,xlm->xkm", M, GL)
                Ghat = jnp.einsum("xlk,xkm->xlm", M, A)
                scalars += plan.k * plan.m * L
            else:
                d = k if self.variant == "k" else self.d[path]
                M2, keys2, Ghat, d_r, err = _ge_update_group(
                    self.M[kk], keys, GL, k, d
                )
                self.M[kk], self.keys[kk] = M2, keys2
                self.sum_d += d * L
                dr_arr = host_fetch(d_r)
                scalars += float(np.sum(plan.k * plan.m + dr_arr * plan.l + dr_arr))
                self.last_err[path] = float(host_fetch(jnp.mean(err)))
                if self.variant == "full":
                    self._round_drmax[path] = max(
                        self._round_drmax.get(path, 0), int(dr_arr.max())
                    )

            if self.ef:
                self.efmem[kk] = GL - Ghat
            recon[path] = _from_matrices(Ghat, plan, v.shape).astype(v.dtype)
        return recon, scalars

    def end_round(self):
        """Formula 13 on the round's max d_r per group -- the same shared-d
        re-bucketing decision the fused engine takes from its single packed
        host transfer."""
        for path, drmax in self._round_drmax.items():
            self.d[path] = ge.next_candidate_count(
                drmax, self.policy.plans[path].k, self.alpha, self.beta
            )
        self._round_drmax = {}


def make_method(name: str, policy: Optional[CompressionPolicy] = None, **kw):
    name = name.lower()
    if name == "fedavg":
        return FedAvgMethod(**kw)
    if name == "topk":
        return TopKMethod(**kw)
    if name == "fedpaq":
        return FedPAQMethod(**kw)
    if name == "signsgd":
        return SignSGDMethod(**kw)
    if name == "fedqclip":
        return FedQClipMethod(**kw)
    if name == "svdfed":
        assert policy is not None
        return SVDFedMethod(policy, **kw)
    if name.startswith("gradestc"):
        assert policy is not None
        variant = "full"
        ef = False
        if "-" in name:
            suffix = name.split("-", 1)[1]
            if suffix == "ef":
                ef = True
            else:
                variant = suffix
        return GradESTCMethod(policy, variant=variant, ef=ef, **kw)
    raise ValueError(f"unknown method {name!r}")
