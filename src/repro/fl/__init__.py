"""repro.fl -- federated-learning runtime.

  * compression -- method shells over the stateless codec protocol
                   (``repro.core.codecs``) + the shared RoundAccountant
                   (exact integer-bit charging, Formula-13 statics)
  * simulation  -- benchmark-scale round runtime with exact byte accounting
                   (entry point; dispatches between the two engines)
  * engine      -- fused client-parallel round, generic over any codec:
                   one jitted XLA program per round (uplink + downlink),
                   one host sync; optionally sharded over a device mesh
                   with a pipelined host loop (DESIGN.md Secs. 8 + 10)

The production SPMD round step (clients = mesh data-axis groups, compressed
all-gather aggregation) lives in ``repro.launch``.
"""

from .compression import make_method
from .simulation import FLConfig, FLResult, default_tiny_arch, run_fl

__all__ = ["make_method", "FLConfig", "FLResult", "default_tiny_arch", "run_fl"]
