"""repro.fl -- federated-learning runtime.

  * compression -- method shells over the stateless codec protocol
                   (``repro.core.codecs``) + the shared RoundAccountant
                   (exact integer-bit charging from packed stats rows)
  * simulation  -- benchmark-scale round runtime with exact byte accounting
                   (entry point; dispatches between the two engines)
  * engine      -- K-round scan-fused client-parallel engine, generic over
                   any codec: one jitted XLA program and one host sync per
                   chunk of ``scan_rounds`` rounds (uplink + downlink,
                   in-jit selection and Formula 13); optionally sharded
                   over a device mesh (DESIGN.md Secs. 8-11)

The production SPMD round step (clients = mesh data-axis groups, compressed
all-gather aggregation) lives in ``repro.launch``.
"""

from .compression import make_method
from .simulation import FLConfig, FLResult, default_tiny_arch, run_fl

__all__ = ["make_method", "FLConfig", "FLResult", "default_tiny_arch", "run_fl"]
