"""repro.fl -- federated-learning runtime.

  * compression -- uplink methods over model-update pytrees (GradESTC + baselines)
  * simulation  -- benchmark-scale round runtime with exact byte accounting
                   (entry point; dispatches between the two engines)
  * engine      -- fused client-parallel round: one jitted XLA program per
                   round, one host sync (DESIGN.md Sec. 8)

The production SPMD round step (clients = mesh data-axis groups, compressed
all-gather aggregation) lives in ``repro.launch``.
"""

from .compression import make_method
from .simulation import FLConfig, FLResult, default_tiny_arch, run_fl

__all__ = ["make_method", "FLConfig", "FLResult", "default_tiny_arch", "run_fl"]
