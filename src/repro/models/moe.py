"""Mixture-of-Experts FFN with top-k routing (granite-moe, dbrx).

GShard/Mesh-style capacity-based dispatch expressed as einsums so GSPMD can
partition it: the expert axis E of the weight banks shards over the "model"
mesh axis (expert parallelism), and the dispatch/combine einsums lower to the
expert all-to-all pattern (DESIGN.md Sec. 5).

Tokens are processed in groups (``moe_group``); each group computes a
(S_g, E, C) dispatch one-hot with per-expert capacity
C = ceil(S_g * top_k * capacity_factor / E).  Overflow tokens fall back to
the residual stream (standard capacity-drop semantics).

The router runs in f32 and its weights are *excluded* from GradESTC
compression (tiny but convergence-critical; see core/policy.py).
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .config import ArchConfig

Params = Dict[str, Any]

__all__ = ["init_moe_ffn", "moe_ffn", "router_load_balance_loss"]

#: tokens per dispatch group; keeps the (S_g, E, C) one-hot bounded.
MOE_GROUP = 4096


def init_moe_ffn(cfg: ArchConfig, key: jax.Array, L: int) -> Params:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    s, sf = 1.0 / math.sqrt(D), 1.0 / math.sqrt(F)
    return {
        "router": jax.random.normal(ks[0], (L, D, E), jnp.float32) * s,
        "moe_wgate": jax.random.normal(ks[1], (L, E, D, F), dt) * s,
        "moe_win": jax.random.normal(ks[2], (L, E, D, F), dt) * s,
        "moe_wout": jax.random.normal(ks[3], (L, E, F, D), dt) * sf,
    }


def _dispatch_one_group(cfg: ArchConfig, x: jnp.ndarray, w: Params) -> jnp.ndarray:
    """x: (S, D) one token group -> (S, D) expert-mixed output."""
    S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_tok
    C = max(1, int(math.ceil(S * K * cfg.capacity_factor / E)))

    logits = x.astype(jnp.float32) @ w["router"]            # (S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)         # (S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # (S, K, E) one-hot of chosen experts
    sel = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
    # position of each (token, choice) within its expert's buffer:
    # cumulative count over the flattened (choice-major) priority order.
    selk = sel.transpose(1, 0, 2).reshape(K * S, E)          # choice-major
    pos_flat = jnp.cumsum(selk, axis=0) - selk               # (K*S, E)
    pos = pos_flat.reshape(K, S, E).transpose(1, 0, 2)       # (S, K, E)
    within_cap = (pos < C) & (sel > 0)

    dt = x.dtype
    slot = jax.nn.one_hot(jnp.sum(pos * sel, axis=-1).astype(jnp.int32), C,
                          dtype=dt)                          # (S, K, C)
    sel_kept = (sel * within_cap).astype(dt)                 # (S, K, E)

    # dispatch (S, E, C): token s occupies slot c of expert e.  Kept in the
    # model dtype -- these are the largest activations of the MoE block.
    if cfg.moe_stop_gradient_dispatch:
        # The one-hot structure is integer-valued: routing indices carry no
        # gradient, only the gate values do.  Without stop_gradient JAX
        # still materializes (and GSPMD gathers) f32 (S, E, C) cotangents
        # through these einsums -- measured 60 GiB of all-gather on
        # granite-moe train_4k (EXPERIMENTS.md SPerf).  Gate gradients flow
        # through the explicit ge factor below.
        mask = jax.lax.stop_gradient(
            jnp.einsum("ske,skc->sec", sel_kept, slot)
        )
        dispatch = mask
        ge_ = jnp.einsum("ske,sk->se", sel_kept, gate_vals.astype(dt))
        combine = mask * ge_[:, :, None]
    else:
        dispatch = jnp.einsum("ske,skc->sec", sel_kept, slot)
        combine = jnp.einsum(
            "ske,skc->sec", sel_kept * gate_vals[..., None].astype(dt), slot
        )

    xe = jnp.einsum("sec,sd->ecd", dispatch, x)              # (E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w["moe_wgate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, w["moe_win"]
    )
    ye = jnp.einsum("ecf,efd->ecd", h, w["moe_wout"])        # (E, C, D)
    return jnp.einsum("sec,ecd->sd", combine, ye)            # (S, D)


def moe_ffn(cfg: ArchConfig, x: jnp.ndarray, w: Params) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D).  Groups tokens to bound dispatch memory."""
    B, S, D = x.shape
    T = B * S
    g = min(cfg.moe_group or MOE_GROUP, T)
    while T % g:
        g -= 1
    xg = x.reshape(T // g, g, D)
    yg = jax.vmap(lambda t: _dispatch_one_group(cfg, t, w))(xg)
    return yg.reshape(B, S, D)


def router_load_balance_loss(cfg: ArchConfig, x: jnp.ndarray, w: Params) -> jnp.ndarray:
    """Switch-style auxiliary load-balance loss (mean over layers is applied
    by the training loop when enabled)."""
    T = x.shape[0] * x.shape[1]
    logits = x.reshape(T, -1).astype(jnp.float32) @ w["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    imp = jnp.mean(probs, axis=0)                            # (E,)
    top1 = jax.nn.one_hot(jnp.argmax(probs, axis=-1), cfg.n_experts)
    load = jnp.mean(top1, axis=0)
    return cfg.n_experts * jnp.sum(imp * load)
