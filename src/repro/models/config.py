"""Architecture configuration dataclass shared by the whole zoo.

One frozen dataclass describes every assigned architecture; family-specific
fields are simply unused elsewhere.  Configs are constructed in
``repro/configs/<arch>.py`` (exact assigned hyperparameters, with source
citations) and each provides a ``reduced()`` smoke variant
(<=2 layers, d_model <= 512, <= 4 experts) per the assignment contract.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

__all__ = ["ArchConfig", "InputShape", "SHAPES"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    experts_per_tok: int = 0
    capacity_factor: float = 1.25

    # --- attention pattern --------------------------------------------------
    sliding_window: int = 0          # 0 = full attention
    #: repeating per-layer pattern; entries in {"global", "local", "rec"}.
    #: () -> all "global".  gemma3: ("local",)*5 + ("global",)
    #: recurrentgemma: ("rec", "rec", "local")
    layer_pattern: Tuple[str, ...] = ()

    # --- positions ----------------------------------------------------------
    pos_type: str = "rope"           # rope | mrope | none | learned
    rope_theta: float = 10000.0

    # --- encoder-decoder (whisper) -------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 1500          # conv-frontend output frames (stubbed)

    # --- vlm (qwen2-vl) -------------------------------------------------------
    vision_tokens: int = 0           # stub patch-embedding prefix length

    # --- ssm (rwkv6) ----------------------------------------------------------
    rwkv_head_dim: int = 64
    time_decay_extra_dim: int = 64   # lora dim for data-dependent decay

    # --- hybrid (recurrentgemma) -----------------------------------------------
    d_rnn: int = 0                   # RG-LRU width (0 -> d_model)
    conv_width: int = 4              # temporal conv1d in recurrent block

    norm_eps: float = 1e-6
    scale_embed: bool = False        # gemma-style sqrt(d) embedding scaling
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True               # activation-checkpoint each layer
    attn_chunk: int = 1024           # kv-chunk for memory-bounded attention
    scan_chunk: int = 256            # time-chunk for recurrent families
    #: unroll factor for the scan over layers.  1 = compact HLO (production);
    #: n_layers = fully unrolled (used by the dry-run cost lowerings so that
    #: cost_analysis counts every layer -- see DESIGN.md Sec. 6).
    scan_unroll: int = 1
    #: unroll the inner query-chunk / loss-chunk scans too (cost lowerings
    #: only -- exact flop counting with production memory access pattern).
    attn_unroll: bool = False
    #: sequence-chunk size for the vocab cross-entropy (bounds the live
    #: logits to (B, ce_chunk, V); the backward recomputes per chunk).
    ce_chunk: int = 512
    # ---- SPerf hillclimb switches (default False = paper-faithful /
    # naive baseline; EXPERIMENTS.md SPerf records before/after) ----------
    #: stop gradients through the MoE dispatch/combine one-hot structure
    #: (router still learns via the gate values); kills the f32 (S, E, C)
    #: cotangent all-gathers in the backward.
    moe_stop_gradient_dispatch: bool = False
    #: pad embed/head vocab to a multiple of this so the head shards over
    #: "model" (Megatron-style); 0 = no padding.
    pad_vocab_multiple: int = 0
    #: MoE dispatch group size (tokens); smaller groups shrink the
    #: (S_g, E, C) one-hots quadratically per group.
    moe_group: int = 4096
    #: contract grouped K/V directly instead of materializing repeat_kv
    #: (H/KV-times less K/V HBM traffic).
    gqa_native: bool = False
    #: force the FL-round grad-accumulation microbatch count (0 = auto from
    #: the activation-memory budget).  Fewer microbatches = fewer FSDP
    #: weight re-gathers/re-streams per round, at more activation memory.
    grad_accum_override: int = 0
    source: str = ""                 # citation for the exact config

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.layer_pattern or ("global",)

    def layer_kinds(self, n: int | None = None) -> Tuple[str, ...]:
        """Expand the repeating pattern over n layers."""
        n = n or self.n_layers
        pat = self.pattern
        return tuple(pat[i % len(pat)] for i in range(n))

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/topology, tiny dimensions."""
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, heads)
        hd = d // heads
        pat = self.pattern
        n_layers = max(2, len(pat)) if len(pat) > 1 else 2
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd if self.head_dim else 0,
            d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 1024),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            experts_per_tok=min(self.experts_per_tok, 2) if self.experts_per_tok else 0,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            encoder_seq=min(self.encoder_seq, 64) if self.encoder_layers else self.encoder_seq,
            vision_tokens=min(self.vision_tokens, 16) if self.vision_tokens else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            d_rnn=min(self.d_rnn, 256) if self.d_rnn else 0,
            time_decay_extra_dim=16,
            attn_chunk=64,
            scan_chunk=16,
            dtype="float32",
            remat=False,
        )


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
