"""Family dispatcher: one API over all assigned architectures.

  init_params(cfg, key)                       -> params pytree
  forward(cfg, params, batch)                 -> logits (B, S, V)
  loss_fn(cfg, params, batch)                 -> scalar CE loss
  init_cache(cfg, batch, max_len, length)     -> cache pytree
  decode_step(cfg, params, cache, tokens)     -> (logits, cache)
  param_group_shapes(cfg)                     -> compression-policy input
  extra_inputs(cfg, B, S)                     -> modality stubs (audio/vision)

``batch`` is a dict: {"tokens", "labels"} plus optional "audio_frames"
(whisper stub) / "vision_embeds" (qwen2-vl stub) / "positions".
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec, rglru, rwkv6, transformer
from .config import ArchConfig

Params = Dict[str, Any]

__all__ = [
    "init_params", "forward", "loss_fn", "init_cache", "decode_step",
    "param_group_shapes", "extra_inputs", "family_module", "count_params",
]

_TRANSFORMER_FAMILIES = ("dense", "moe", "vlm")


def family_module(cfg: ArchConfig):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer
    if cfg.family == "ssm":
        return rwkv6
    if cfg.family == "hybrid":
        return rglru
    if cfg.family == "encdec":
        return encdec
    raise ValueError(f"unknown family {cfg.family!r}")


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    return family_module(cfg).init_params(cfg, key)


def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    mod = family_module(cfg)
    kwargs = {}
    if cfg.family == "vlm" and "vision_embeds" in batch:
        kwargs["vision_embeds"] = batch["vision_embeds"]
        if "positions" in batch:
            kwargs["positions"] = batch["positions"]
    if cfg.family == "encdec" and "audio_frames" in batch:
        kwargs["audio_frames"] = batch["audio_frames"]
    return mod.forward(cfg, params, batch["tokens"], **kwargs)


def forward_hidden(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray]):
    """(hidden (B, S_total, D), head (D, V)) without materializing logits."""
    mod = family_module(cfg)
    kwargs = {}
    if cfg.family == "vlm" and "vision_embeds" in batch:
        kwargs["vision_embeds"] = batch["vision_embeds"]
        if "positions" in batch:
            kwargs["positions"] = batch["positions"]
    if cfg.family == "encdec" and "audio_frames" in batch:
        kwargs["audio_frames"] = batch["audio_frames"]
    return mod.forward_hidden(cfg, params, batch["tokens"], **kwargs)


def loss_fn(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Next-token cross-entropy, mean over tokens, f32.

    The vocabulary projection + CE are evaluated in sequence chunks of
    ``cfg.ce_chunk`` under jax.checkpoint, so the live logits tensor is
    (B, ce_chunk, V) instead of (B, S, V) -- with V up to 262k this is the
    difference between fitting v5e HBM and a 10x overshoot."""
    hidden, head = forward_hidden(cfg, params, batch)
    labels = batch["labels"]
    # vlm prefix tokens carry no labels: align to the trailing label length
    if hidden.shape[1] != labels.shape[1]:
        hidden = hidden[:, -labels.shape[1]:, :]
    B, S, D = hidden.shape
    cs = min(cfg.ce_chunk, S)
    while S % cs:
        cs -= 1
    nc = S // cs

    V = head.shape[-1]
    # padded-vocab columns (pad_vocab_multiple) must not leak probability
    pad_bias = (
        jnp.where(jnp.arange(V) < cfg.vocab, 0.0, -1e30).astype(jnp.float32)
        if V != cfg.vocab else None
    )

    def chunk_ce(h_c, y_c):
        logits = (h_c @ head).astype(jnp.float32)          # (B, cs, V)
        if pad_bias is not None:
            logits = logits + pad_bias
        logz = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction instead of take_along_axis: a gather over the
        # vocab axis would force GSPMD to all-gather the sharded logits.
        onehot = jax.nn.one_hot(y_c, V, dtype=logits.dtype)
        gold = jnp.sum(logits * onehot, axis=-1)
        return jnp.sum(logz - gold)

    if nc == 1:
        return chunk_ce(hidden, labels) / (B * S)

    hs = hidden.reshape(B, nc, cs, D).swapaxes(0, 1)       # (nc, B, cs, D)
    ys = labels.reshape(B, nc, cs).swapaxes(0, 1)

    def body(tot, xs):
        h_c, y_c = xs
        return tot + chunk_ce(h_c, y_c), None

    body_ck = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    total, _ = jax.lax.scan(body_ck, jnp.zeros((), jnp.float32), (hs, ys),
                            unroll=nc if cfg.attn_unroll else 1)
    return total / (B * S)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, length=0, **kw):
    return family_module(cfg).init_cache(cfg, batch, max_len, length, **kw)


def decode_step(cfg: ArchConfig, params: Params, cache, tokens: jnp.ndarray):
    return family_module(cfg).decode_step(cfg, params, cache, tokens)


def param_group_shapes(cfg: ArchConfig):
    return family_module(cfg).param_group_shapes(cfg)


def extra_inputs(cfg: ArchConfig, batch: int, seq: int, dtype=None) -> Dict[str, jnp.ndarray]:
    """Modality-frontend stubs (the one allowed stub: precomputed embeddings)."""
    dt = jnp.dtype(dtype or cfg.dtype)
    out: Dict[str, jnp.ndarray] = {}
    if cfg.family == "encdec":
        out["audio_frames"] = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), dt)
    if cfg.family == "vlm" and cfg.vision_tokens:
        out["vision_embeds"] = jnp.zeros((batch, cfg.vision_tokens, cfg.d_model), dt)
    return out


def count_params(params: Params) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree.leaves(params))
