"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

Assignment carve-out: the mel-spectrogram + conv feature extractor is a STUB
-- ``input_specs()`` supplies precomputed frame embeddings (B, frames, D),
and this module implements the transformer that consumes them:

  * encoder: bidirectional self-attention + GELU MLP, learned positions;
  * decoder: causal self-attention + cross-attention to the encoder output
    + GELU MLP, learned positions.

Decode path: the encoder output (and its per-layer cross K/V projections)
are computed once at prefill; each decode step appends one token to the
decoder self-attention KV cache and re-reads the fixed cross K/V.
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import attention, decode_attention, layer_norm, repeat_kv

Params = Dict[str, Any]

__all__ = [
    "init_params", "forward", "forward_hidden", "encode_audio", "init_cache", "decode_step",
    "EncDecCache", "param_group_shapes",
]


class EncDecCache(NamedTuple):
    self_k: jnp.ndarray      # (L, B, S, H, hd)
    self_v: jnp.ndarray      # (L, B, S, H, hd)
    cross_k: jnp.ndarray     # (L, B, F, H, hd) -- fixed after prefill
    cross_v: jnp.ndarray     # (L, B, F, H, hd)
    length: jnp.ndarray      # () int32


def _init_attn_block(key, L, D, H, hd, dt):
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(D)
    return {
        "wq": jax.random.normal(ks[0], (L, D, H * hd), dt) * s,
        "wk": jax.random.normal(ks[1], (L, D, H * hd), dt) * s,
        "wv": jax.random.normal(ks[2], (L, D, H * hd), dt) * s,
        "wo": jax.random.normal(ks[3], (L, H * hd, D), dt) * (1.0 / math.sqrt(H * hd)),
    }


def _init_stack(cfg: ArchConfig, key: jax.Array, L: int, cross: bool) -> Params:
    D, F, H, hd = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.hd
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "ln1_w": jnp.ones((L, D), dt), "ln1_b": jnp.zeros((L, D), dt),
        "ln_mlp_w": jnp.ones((L, D), dt), "ln_mlp_b": jnp.zeros((L, D), dt),
        "self": _init_attn_block(ks[0], L, D, H, hd, dt),
        "mlp_win": jax.random.normal(ks[1], (L, D, F), dt) / math.sqrt(D),
        "mlp_bin": jnp.zeros((L, F), dt),
        "mlp_wout": jax.random.normal(ks[2], (L, F, D), dt) / math.sqrt(F),
        "mlp_bout": jnp.zeros((L, D), dt),
    }
    if cross:
        p["ln2_w"] = jnp.ones((L, D), dt)
        p["ln2_b"] = jnp.zeros((L, D), dt)
        p["cross"] = _init_attn_block(ks[3], L, D, H, hd, dt)
    return p


def _padded_vocab(cfg: ArchConfig) -> int:
    m = cfg.pad_vocab_multiple
    return cfg.vocab if not m else ((cfg.vocab + m - 1) // m) * m


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    D, V = cfg.d_model, _padded_vocab(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "enc_pos": jax.random.normal(ks[0], (cfg.encoder_seq, D), dt) * 0.02,
        "dec_pos": jax.random.normal(ks[1], (32768, D), dt) * 0.02,
        "embed": jax.random.normal(ks[2], (V, D), dt) * 0.02,
        "enc": _init_stack(cfg, ks[3], cfg.encoder_layers, cross=False),
        "dec": _init_stack(cfg, ks[4], cfg.n_layers, cross=True),
        "ln_enc_w": jnp.ones((D,), dt), "ln_enc_b": jnp.zeros((D,), dt),
        "ln_dec_w": jnp.ones((D,), dt), "ln_dec_b": jnp.zeros((D,), dt),
    }


def _self_attn(cfg, w, h, *, causal, q_chunk=0, unroll=False):
    B, S, D = h.shape
    H, hd = cfg.n_heads, cfg.hd
    q = (h @ w["wq"]).reshape(B, S, H, hd)
    k = (h @ w["wk"]).reshape(B, S, H, hd)
    v = (h @ w["wv"]).reshape(B, S, H, hd)
    o = attention(q, k, v, causal=causal, q_chunk=q_chunk, unroll=unroll)
    return o.reshape(B, S, H * hd) @ w["wo"]


def _cross_attn(cfg, w, h, enc_out):
    B, S, D = h.shape
    H, hd = cfg.n_heads, cfg.hd
    q = (h @ w["wq"]).reshape(B, S, H, hd)
    k = (enc_out @ w["wk"]).reshape(B, enc_out.shape[1], H, hd)
    v = (enc_out @ w["wv"]).reshape(B, enc_out.shape[1], H, hd)
    o = attention(q, k, v, causal=False)
    return o.reshape(B, S, H * hd) @ w["wo"]


def _mlp(w, h):
    y = jax.nn.gelu((h @ w["mlp_win"] + w["mlp_bin"]).astype(jnp.float32),
                    approximate=True).astype(h.dtype)
    return y @ w["mlp_wout"] + w["mlp_bout"]


def encode_audio(cfg: ArchConfig, params: Params, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, F, D) stubbed conv-frontend output -> encoder states."""
    dt = jnp.dtype(cfg.dtype)
    x = frames.astype(dt) + params["enc_pos"][None, : frames.shape[1]]
    eps = cfg.norm_eps

    def body(xc, w):
        h = layer_norm(xc, w["ln1_w"], w["ln1_b"], eps)
        xc = xc + _self_attn(cfg, w["self"], h, causal=False, q_chunk=cfg.attn_chunk,
                             unroll=cfg.attn_unroll)
        h = layer_norm(xc, w["ln_mlp_w"], w["ln_mlp_b"], eps)
        return xc + _mlp(w, h), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc"], unroll=cfg.scan_unroll)
    return layer_norm(x, params["ln_enc_w"], params["ln_enc_b"], eps)


def forward_hidden(
    cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
    audio_frames: Optional[jnp.ndarray] = None, **_
):
    """Training / prefill forward up to the final norm."""
    dt = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    if audio_frames is None:
        audio_frames = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), dt)
    enc_out = encode_audio(cfg, params, audio_frames)
    eps = cfg.norm_eps
    x = params["embed"][tokens].astype(dt) + params["dec_pos"][None, :S]

    def body(xc, w):
        h = layer_norm(xc, w["ln1_w"], w["ln1_b"], eps)
        xc = xc + _self_attn(cfg, w["self"], h, causal=True, q_chunk=cfg.attn_chunk,
                             unroll=cfg.attn_unroll)
        h = layer_norm(xc, w["ln2_w"], w["ln2_b"], eps)
        xc = xc + _cross_attn(cfg, w["cross"], h, enc_out)
        h = layer_norm(xc, w["ln_mlp_w"], w["ln_mlp_b"], eps)
        return xc + _mlp(w, h), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec"], unroll=cfg.scan_unroll)
    x = layer_norm(x, params["ln_dec_w"], params["ln_dec_b"], eps)
    return x, params["embed"].T                          # whisper ties head


def forward(cfg: ArchConfig, params: Params, tokens: jnp.ndarray,
            audio_frames: Optional[jnp.ndarray] = None, **_) -> jnp.ndarray:
    x, head = forward_hidden(cfg, params, tokens, audio_frames=audio_frames)
    return (x @ head).astype(jnp.float32)[..., : cfg.vocab]


def init_cache(cfg: ArchConfig, batch: int, max_len: int, length=0,
               enc_out: Optional[jnp.ndarray] = None,
               params: Optional[Params] = None) -> EncDecCache:
    dt = jnp.dtype(cfg.dtype)
    L, H, hd, Fr = cfg.n_layers, cfg.n_heads, cfg.hd, cfg.encoder_seq
    if enc_out is not None and params is not None:
        # vectorized per-layer cross projections
        ck = jnp.einsum("bfd,ldh->lbfh", enc_out, params["dec"]["cross"]["wk"]).reshape(
            L, batch, Fr, H, hd)
        cv = jnp.einsum("bfd,ldh->lbfh", enc_out, params["dec"]["cross"]["wv"]).reshape(
            L, batch, Fr, H, hd)
    else:
        ck = jnp.zeros((L, batch, Fr, H, hd), dt)
        cv = jnp.zeros((L, batch, Fr, H, hd), dt)
    return EncDecCache(
        self_k=jnp.zeros((L, batch, max_len, H, hd), dt),
        self_v=jnp.zeros((L, batch, max_len, H, hd), dt),
        cross_k=ck.astype(dt), cross_v=cv.astype(dt),
        length=jnp.asarray(length, jnp.int32),
    )


def decode_step(cfg: ArchConfig, params: Params, cache: EncDecCache,
                tokens: jnp.ndarray) -> Tuple[jnp.ndarray, EncDecCache]:
    dt = jnp.dtype(cfg.dtype)
    eps = cfg.norm_eps
    B = tokens.shape[0]
    H, hd = cfg.n_heads, cfg.hd
    x = params["embed"][tokens].astype(dt) + params["dec_pos"][cache.length][None, None]

    def body(carry, lw):
        (x,) = carry
        w, sk, sv, ck, cv = lw
        h = layer_norm(x, w["ln1_w"], w["ln1_b"], eps)
        q = (h @ w["self"]["wq"]).reshape(B, 1, H, hd)
        k = (h @ w["self"]["wk"]).reshape(B, 1, H, hd)
        v = (h @ w["self"]["wv"]).reshape(B, 1, H, hd)
        sk = jax.lax.dynamic_update_slice(sk, k, (0, cache.length, 0, 0))
        sv = jax.lax.dynamic_update_slice(sv, v, (0, cache.length, 0, 0))
        o = decode_attention(q, sk, sv, cache.length + 1)
        x = x + o.reshape(B, 1, H * hd) @ w["self"]["wo"]
        h = layer_norm(x, w["ln2_w"], w["ln2_b"], eps)
        q = (h @ w["cross"]["wq"]).reshape(B, 1, H, hd)
        o = decode_attention(q, ck, cv, jnp.asarray(ck.shape[1], jnp.int32))
        x = x + o.reshape(B, 1, H * hd) @ w["cross"]["wo"]
        h = layer_norm(x, w["ln_mlp_w"], w["ln_mlp_b"], eps)
        return (x + _mlp(w, h),), (sk, sv)

    (x,), (sk, sv) = jax.lax.scan(
        body, (x,), (params["dec"], cache.self_k, cache.self_v,
                     cache.cross_k, cache.cross_v)
    )
    x = layer_norm(x, params["ln_dec_w"], params["ln_dec_b"], eps)
    logits = (x @ params["embed"].T).astype(jnp.float32)[..., : cfg.vocab]
    return logits, EncDecCache(self_k=sk, self_v=sv, cross_k=cache.cross_k,
                               cross_v=cache.cross_v, length=cache.length + 1)


def param_group_shapes(cfg: ArchConfig):
    D, F, H, hd, V = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.hd, cfg.vocab
    Le, Ld = cfg.encoder_layers, cfg.n_layers
    g = {}
    for pre, L in (("enc", Le), ("dec", Ld)):
        g.update({
            f"{pre}/self/wq": ((D, H * hd), L), f"{pre}/self/wk": ((D, H * hd), L),
            f"{pre}/self/wv": ((D, H * hd), L), f"{pre}/self/wo": ((H * hd, D), L),
            f"{pre}/mlp_win": ((D, F), L), f"{pre}/mlp_wout": ((F, D), L),
        })
    g.update({
        "dec/cross/wq": ((D, H * hd), Ld), "dec/cross/wk": ((D, H * hd), Ld),
        "dec/cross/wv": ((D, H * hd), Ld), "dec/cross/wo": ((H * hd, D), Ld),
        "embed": ((_padded_vocab(cfg), D), 1),
    })
    return g
