"""Shared neural-net layers: norms, RoPE / M-RoPE, GQA attention, SwiGLU.

Everything is a pure function over explicit parameter pytrees (no framework
modules), so stacks can be scanned/vmapped and sharded with pjit directly.

Numerical policy: parameters and activations in the config dtype (bf16 for
production configs), normalization statistics and softmax in f32.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm", "layer_norm",
    "rope_table", "apply_rope", "apply_mrope",
    "attention", "decode_attention", "repeat_kv",
    "swiglu", "gelu_mlp",
    "KVCache",
]


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    rrms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return ((x32 * rrms) * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary positions
# --------------------------------------------------------------------------

def rope_table(positions: jnp.ndarray, head_dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for given positions.  positions: (..., S) int32.
    Returns (cos, sin) of shape (..., S, head_dim//2), f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs     # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, hd); cos/sin: (B, S, half) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Standard RoPE.  x: (B, S, H, hd); positions: (B, S) or (S,)."""
    if positions.ndim == 1:
        positions = positions[None, :]
    cos, sin = rope_table(positions, x.shape[-1], theta)
    return _rotate(x, cos, sin)


def apply_mrope(
    x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
    sections: Tuple[float, float, float] = (0.25, 0.375, 0.375),
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE [arXiv:2409.12191].

    The rotary frequency dims are split into three contiguous sections fed by
    (temporal, height, width) position ids.  positions3: (3, B, S).
    For pure text the three id streams are identical, recovering 1-D RoPE.
    """
    half = x.shape[-1] // 2
    s0 = int(half * sections[0])
    s1 = int(half * sections[1])
    bounds = (s0, s0 + s1)
    cos_parts, sin_parts = [], []
    lo = 0
    for i, hi in enumerate((*bounds, half)):
        freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)[lo:hi]
        ang = positions3[i].astype(jnp.float32)[..., None] * freqs
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        lo = hi
    cos = jnp.concatenate(cos_parts, axis=-1)    # (B, S, half)
    sin = jnp.concatenate(sin_parts, axis=-1)
    return _rotate(x, cos, sin)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def repeat_kv(kv: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, KV*n_rep, hd) by head repetition (GQA)."""
    if n_rep == 1:
        return kv
    b, s, h, d = kv.shape
    return jnp.broadcast_to(kv[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def _mask_bias(sq: int, skv: int, q_offset, *, causal: bool, window) -> jnp.ndarray:
    """Additive f32 mask bias (sq, skv).  q_offset: absolute position of query
    row 0 relative to kv col 0.  ``window`` may be a Python int or a traced
    scalar (per-layer local/global patterns scan it alongside the weights);
    window <= 0 means full attention."""
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), jnp.bool_)
    if causal:
        ok &= kpos <= qpos
    w = jnp.asarray(window, jnp.int32)
    ok &= (w <= 0) | (kpos > qpos - w)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *, causal: bool = True, window=0, q_chunk: int = 0,
    softmax_scale: float | None = None, unroll: bool = False,
) -> jnp.ndarray:
    """Multi-head attention over full sequences (train / prefill).

    q: (B, Sq, H, hd); k, v: (B, Skv, H, hd) (kv already GQA-repeated).
    ``q_chunk`` > 0 bounds memory by scanning over query blocks (the flash-
    attention access pattern expressed in pure JAX; the materialized scores
    are (B, H, q_chunk, Skv) per step instead of (B, H, Sq, Skv)).
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)

    def blk(qb: jnp.ndarray, off) -> jnp.ndarray:
        # bf16 operands, f32 accumulation (preferred_element_type): casting
        # k/v to f32 instead would make XLA hoist a full-stack f32 copy of
        # the weights/caches out of the layer scan.
        s = jnp.einsum("bqhd,bkhd->bhqk", qb * jnp.asarray(scale, qb.dtype), k,
                       preferred_element_type=jnp.float32)
        s = s + _mask_bias(qb.shape[1], Skv, off, causal=causal, window=window)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                          preferred_element_type=jnp.float32).astype(q.dtype)

    if not q_chunk or Sq <= q_chunk:
        return blk(q, 0)

    # pad ragged sequence lengths (e.g. a vision prefix) to a chunk multiple
    # rather than falling back to the materialized (Sq, Skv) score matrix.
    pad = (-Sq) % q_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    Sq_p = Sq + pad
    nq = Sq_p // q_chunk
    qs = qp.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)

    def body(_, qi_i):
        qi, i = qi_i
        return None, blk(qi, i * q_chunk)

    _, out = jax.lax.scan(body, None, (qs, jnp.arange(nq)),
                          unroll=nq if unroll else 1)
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, Sq_p, H, hd)
    return out[:, :Sq] if pad else out


def decode_attention(
    q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
    length: jnp.ndarray, *, window=0, softmax_scale: float | None = None,
) -> jnp.ndarray:
    """Single-token attention against a KV cache.

    q: (B, 1, H, hd); caches: (B, S, H, hd); length: () or (B,) valid length.
    Written so that when the cache's S axis is sharded, XLA's partial-softmax
    reductions realize the flash-decoding LSE merge across shards.
    """
    B, S, H, hd = k_cache.shape
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q * jnp.asarray(scale, q.dtype), k_cache,
                   preferred_element_type=jnp.float32)    # (B, H, 1, S)
    kpos = jnp.arange(S)[None, None, None, :]
    lb = jnp.asarray(length).reshape(-1, 1, 1, 1)
    ok = kpos < lb
    w = jnp.asarray(window, jnp.int32)
    ok &= (w <= 0) | (kpos >= lb - w)
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v_cache.dtype), v_cache,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def attention_gqa(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *, causal: bool = True, window=0, q_chunk: int = 0,
    softmax_scale: float | None = None, unroll: bool = False,
) -> jnp.ndarray:
    """Grouped-query attention without materializing repeated K/V.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with H = KV * G.  The repeat
    in vanilla ``attention(repeat_kv(k, G), ...)`` writes/reads a G-times
    larger K/V to HBM; here the einsum contracts the grouped layout
    directly (SPerf optimization; flag ArchConfig.gqa_native)."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, KV, G, hd)

    def blk(qb: jnp.ndarray, off) -> jnp.ndarray:
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb * jnp.asarray(scale, qb.dtype),
                       k, preferred_element_type=jnp.float32)
        s = s + _mask_bias(qb.shape[1], Skv, off, causal=causal, window=window)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.astype(q.dtype)

    if not q_chunk or Sq <= q_chunk:
        return blk(qg, 0).reshape(B, Sq, H, hd)
    pad = (-Sq) % q_chunk
    qp = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0))) if pad else qg
    Sq_p = Sq + pad
    nq = Sq_p // q_chunk
    qs = qp.reshape(B, nq, q_chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def body(_, qi_i):
        qi, i = qi_i
        return None, blk(qi, i * q_chunk)

    _, out = jax.lax.scan(body, None, (qs, jnp.arange(nq)),
                          unroll=nq if unroll else 1)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq_p, H, hd)
    return out[:, :Sq] if pad else out


def decode_attention_gqa(
    q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
    length: jnp.ndarray, *, window=0, softmax_scale: float | None = None,
) -> jnp.ndarray:
    """Single-token GQA attention against an un-repeated (B, S, KV, hd)
    cache."""
    B, S, KV, hd = k_cache.shape
    H = q.shape[2]
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg * jnp.asarray(scale, q.dtype),
                   k_cache, preferred_element_type=jnp.float32)
    kpos = jnp.arange(S)[None, None, None, None, :]
    lb = jnp.asarray(length).reshape(-1, 1, 1, 1, 1)
    ok = kpos < lb
    w = jnp.asarray(window, jnp.int32)
    ok &= (w <= 0) | (kpos >= lb - w)
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, S, KV, hd)
    v: jnp.ndarray        # (B, S, KV, hd)
    length: jnp.ndarray   # () int32 -- tokens already in cache


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ w_gate) * (x @ w_in)
    return h @ w_out


def gelu_mlp(x: jnp.ndarray, w_in: jnp.ndarray, b_in: jnp.ndarray,
             w_out: jnp.ndarray, b_out: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.gelu((x @ w_in + b_in).astype(jnp.float32), approximate=True).astype(x.dtype)
    return h @ w_out + b_out
