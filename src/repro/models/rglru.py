"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention
[arXiv:2402.19427].

Repeating layer pattern ("rec", "rec", "local"): two recurrent residual
blocks followed by one local (sliding-window, kv=1 MQA) attention block.
Every residual block is temporal-mix + GeGLU MLP with pre-RMSNorm.

RG-LRU recurrence (diagonal, per channel; c = 8):

    r_t = sigmoid(W_rg xb_t)            # recurrence gate
    i_t = sigmoid(W_ig xb_t)            # input gate
    log a_t = c * r_t * logsigmoid(Lambda)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * xb_t)

Training evaluates the recurrence with ``jax.lax.associative_scan`` (the
linear recurrence composes associatively), which parallelizes over time --
the TPU-native alternative to a sequential CUDA scan kernel (DESIGN.md
Sec. 3).  Decode is one step, so the hybrid runs long_500k.

Layer stacking: the pattern repeats ``L // 3`` times and is scanned
block-wise; the ``L % 3`` leftover layers run unscanned (at most 2).
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (
    apply_rope, attention, decode_attention, repeat_kv, rms_norm,
)

Params = Dict[str, Any]

__all__ = [
    "init_params", "forward", "forward_hidden", "init_cache", "decode_step",
    "HybridCache", "param_group_shapes",
]

_LRU_C = 8.0


class HybridCache(NamedTuple):
    # attention layers (one stack):
    k: jnp.ndarray         # (La, B, S, KV, hd)
    v: jnp.ndarray         # (La, B, S, KV, hd)
    # recurrent layers (one stack):
    h: jnp.ndarray         # (Lr, B, R) LRU state
    conv: jnp.ndarray      # (Lr, B, cw-1, R) conv tail
    length: jnp.ndarray    # () int32


def _dims(cfg: ArchConfig) -> Tuple[int, int]:
    return cfg.d_model, cfg.d_rnn or cfg.d_model


def _counts(cfg: ArchConfig) -> Tuple[int, int]:
    kinds = cfg.layer_kinds()
    n_rec = sum(k == "rec" for k in kinds)
    return n_rec, len(kinds) - n_rec


def _init_mlp(cfg, key, L):
    D, F = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(D)
    return {
        "ln_mlp": jnp.zeros((L, D), dt),
        "mlp_wgate": jax.random.normal(k1, (L, D, F), dt) * s,
        "mlp_win": jax.random.normal(k2, (L, D, F), dt) * s,
        "mlp_wout": jax.random.normal(k3, (L, F, D), dt) * (1.0 / math.sqrt(F)),
    }


def _init_rec(cfg: ArchConfig, key: jax.Array, L: int) -> Params:
    D, R = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(D)
    sr = 1.0 / math.sqrt(R)
    p = {
        "ln": jnp.zeros((L, D), dt),
        "w_y": jax.random.normal(ks[0], (L, D, R), dt) * s,
        "w_x": jax.random.normal(ks[1], (L, D, R), dt) * s,
        "conv_k": jax.random.normal(ks[2], (L, cfg.conv_width, R), dt) * 0.1,
        "w_rg": jax.random.normal(ks[3], (L, R, R), dt) * sr,
        "w_ig": jax.random.normal(ks[4], (L, R, R), dt) * sr,
        "lru_lambda": jnp.full((L, R), 3.0, jnp.float32),   # a ~ sigmoid(3)
        "w_o": jax.random.normal(ks[5], (L, R, D), dt) * sr,
    }
    p.update(_init_mlp(cfg, ks[6], L))
    return p


def _init_attn(cfg: ArchConfig, key: jax.Array, L: int) -> Params:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(D)
    p = {
        "ln": jnp.zeros((L, D), dt),
        "wq": jax.random.normal(ks[0], (L, D, H * hd), dt) * s,
        "wk": jax.random.normal(ks[1], (L, D, KV * hd), dt) * s,
        "wv": jax.random.normal(ks[2], (L, D, KV * hd), dt) * s,
        "wo": jax.random.normal(ks[3], (L, H * hd, D), dt) * (1.0 / math.sqrt(H * hd)),
    }
    p.update(_init_mlp(cfg, ks[4], L))
    return p


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    n_rec, n_attn = _counts(cfg)
    kE, kR, kA, kH = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    D, V = cfg.d_model, cfg.vocab
    params = {
        "embed": jax.random.normal(kE, (V, D), dt) * 0.02,
        "rec": _init_rec(cfg, kR, n_rec),
        "attn": _init_attn(cfg, kA, n_attn),
        "ln_f": jnp.zeros((D,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(kH, (D, V), dt) / math.sqrt(D)
    return params


def _geglu(w: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    h = rms_norm(x, w["ln_mlp"], eps)
    y = jax.nn.gelu((h @ w["mlp_wgate"]).astype(jnp.float32), approximate=True)
    return x + (y.astype(x.dtype) * (h @ w["mlp_win"])) @ w["mlp_wout"]


def _causal_conv(xb: jnp.ndarray, kern: jnp.ndarray, tail: jnp.ndarray | None):
    """Depthwise causal conv1d.  xb: (B, T, R); kern: (cw, R);
    tail: (B, cw-1, R) previous context or None (zeros)."""
    cw = kern.shape[0]
    if tail is None:
        tail = jnp.zeros((xb.shape[0], cw - 1, xb.shape[2]), xb.dtype)
    xp = jnp.concatenate([tail, xb], axis=1)                 # (B, T+cw-1, R)
    out = sum(xp[:, i : i + xb.shape[1], :] * kern[i] for i in range(cw))
    return out, xp[:, -(cw - 1):, :] if cw > 1 else tail


def _lru_scan(log_a: jnp.ndarray, bx: jnp.ndarray, h0: jnp.ndarray | None):
    """h_t = a_t h_{t-1} + b_t via associative scan over T.
    log_a, bx: (B, T, R) f32.  h0: (B, R) initial state or None."""
    a = jnp.exp(log_a)
    if h0 is not None:
        bx = bx.at[:, 0, :].add(a[:, 0, :] * h0)
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def _rec_temporal(cfg, w, x, h0, conv_tail, eps):
    """RG-LRU temporal block.  Returns (out, h_T, conv_tail)."""
    D, R = _dims(cfg)
    hN = rms_norm(x, w["ln"], eps)
    y = jax.nn.gelu((hN @ w["w_y"]).astype(jnp.float32), approximate=True)
    xb = hN @ w["w_x"]
    xb, new_tail = _causal_conv(xb, w["conv_k"], conv_tail)
    xb32 = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(xb32 @ w["w_rg"].astype(jnp.float32))
    i = jax.nn.sigmoid(xb32 @ w["w_ig"].astype(jnp.float32))
    log_a = _LRU_C * r * jax.nn.log_sigmoid(w["lru_lambda"])
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * xb32)
    h = _lru_scan(log_a, b, h0)                               # (B, T, R)
    out = (y * h).astype(x.dtype) @ w["w_o"]
    return x + out, h[:, -1, :], new_tail


def _attn_temporal(cfg, w, x, positions, eps):
    B, T, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    hN = rms_norm(x, w["ln"], eps)
    q = apply_rope((hN @ w["wq"]).reshape(B, T, H, hd), positions, cfg.rope_theta)
    k = apply_rope((hN @ w["wk"]).reshape(B, T, KV, hd), positions, cfg.rope_theta)
    v = (hN @ w["wv"]).reshape(B, T, KV, hd)
    o = attention(q, repeat_kv(k, H // KV), repeat_kv(v, H // KV),
                  causal=True, window=cfg.sliding_window, q_chunk=cfg.attn_chunk,
                  unroll=cfg.attn_unroll)
    return x + o.reshape(B, T, H * hd) @ w["wo"]


def forward_hidden(cfg: ArchConfig, params: Params, tokens: jnp.ndarray, **_):
    dt = jnp.dtype(cfg.dtype)
    eps = cfg.norm_eps
    B, T = tokens.shape
    D, R = _dims(cfg)
    x = params["embed"][tokens].astype(dt)
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(D), dt)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    kinds = cfg.layer_kinds()
    pat = cfg.pattern
    n_blocks = cfg.n_layers // len(pat)
    rec_per_block = sum(k == "rec" for k in pat)
    attn_per_block = len(pat) - rec_per_block

    def take(stack, i, cnt):
        return jax.tree.map(lambda a: jax.lax.dynamic_slice_in_dim(a, i, cnt, 0), stack)

    def block(xc, idx):
        ri, ai = idx * rec_per_block, idx * attn_per_block
        j_r, j_a = 0, 0
        for kind in pat:
            if kind == "rec":
                w = jax.tree.map(lambda a: a[0], take(params["rec"], ri + j_r, 1))
                xc, _, _ = _rec_temporal(cfg, w, xc, None, None, eps)
                xc = _geglu(w, xc, eps)
                j_r += 1
            else:
                w = jax.tree.map(lambda a: a[0], take(params["attn"], ai + j_a, 1))
                xc = _attn_temporal(cfg, w, xc, positions, eps)
                xc = _geglu(w, xc, eps)
                j_a += 1
        return xc, None

    body = block
    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, jnp.arange(n_blocks), unroll=cfg.scan_unroll)

    # leftover layers (pattern prefix), unscanned -- at most len(pat)-1
    n_rec_used = n_blocks * rec_per_block
    n_attn_used = n_blocks * attn_per_block
    for kind in kinds[n_blocks * len(pat):]:
        if kind == "rec":
            w = jax.tree.map(lambda a: a[n_rec_used], params["rec"])
            x, _, _ = _rec_temporal(cfg, w, x, None, None, eps)
            x = _geglu(w, x, eps)
            n_rec_used += 1
        else:
            w = jax.tree.map(lambda a: a[n_attn_used], params["attn"])
            x = _attn_temporal(cfg, w, x, positions, eps)
            x = _geglu(w, x, eps)
            n_attn_used += 1

    x = rms_norm(x, params["ln_f"], eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return x, head


def forward(cfg: ArchConfig, params: Params, tokens: jnp.ndarray, **kw) -> jnp.ndarray:
    x, head = forward_hidden(cfg, params, tokens, **kw)
    return (x @ head).astype(jnp.float32)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, length=0) -> HybridCache:
    dt = jnp.dtype(cfg.dtype)
    n_rec, n_attn = _counts(cfg)
    D, R = _dims(cfg)
    KV, hd = cfg.n_kv_heads, cfg.hd
    # local layers only ever see ``sliding_window`` keys; cap the cache there
    s_attn = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return HybridCache(
        k=jnp.zeros((n_attn, batch, s_attn, KV, hd), dt),
        v=jnp.zeros((n_attn, batch, s_attn, KV, hd), dt),
        h=jnp.zeros((n_rec, batch, R), jnp.float32),
        conv=jnp.zeros((n_rec, batch, cfg.conv_width - 1, R), dt),
        length=jnp.asarray(length, jnp.int32),
    )


def decode_step(cfg: ArchConfig, params: Params, cache: HybridCache,
                tokens: jnp.ndarray) -> Tuple[jnp.ndarray, HybridCache]:
    """One token.  Local-attention caches are ring buffers of size window."""
    dt = jnp.dtype(cfg.dtype)
    eps = cfg.norm_eps
    B = tokens.shape[0]
    D, R = _dims(cfg)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    x = params["embed"][tokens].astype(dt)
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(D), dt)
    pos = jnp.broadcast_to(cache.length[None, None], (B, 1))
    S_buf = cache.k.shape[2]
    slot = cache.length % S_buf

    kinds = cfg.layer_kinds()
    k_all, v_all = cache.k, cache.v
    h_all, conv_all = cache.h, cache.conv
    ri = ai = 0
    for kind in kinds:
        if kind == "rec":
            w = jax.tree.map(lambda a: a[ri], params["rec"])
            x, h_new, tail = _rec_temporal(
                cfg, w, x, h_all[ri], conv_all[ri], eps
            )
            x = _geglu(w, x, eps)
            h_all = h_all.at[ri].set(h_new)
            conv_all = conv_all.at[ri].set(tail)
            ri += 1
        else:
            w = jax.tree.map(lambda a: a[ai], params["attn"])
            hN = rms_norm(x, w["ln"], eps)
            q = apply_rope((hN @ w["wq"]).reshape(B, 1, H, hd), pos, cfg.rope_theta)
            k = apply_rope((hN @ w["wk"]).reshape(B, 1, KV, hd), pos, cfg.rope_theta)
            v = (hN @ w["wv"]).reshape(B, 1, KV, hd)
            kc = jax.lax.dynamic_update_slice(k_all[ai], k, (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(v_all[ai], v, (0, slot, 0, 0))
            valid = jnp.minimum(cache.length + 1, S_buf)
            o = decode_attention(q, repeat_kv(kc, H // KV), repeat_kv(vc, H // KV),
                                 valid, window=0)
            x = x + o.reshape(B, 1, H * hd) @ w["wo"]
            x = _geglu(w, x, eps)
            k_all = k_all.at[ai].set(kc)
            v_all = v_all.at[ai].set(vc)
            ai += 1
    x = rms_norm(x, params["ln_f"], eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (x @ head).astype(jnp.float32)
    return logits, HybridCache(k=k_all, v=v_all, h=h_all, conv=conv_all,
                               length=cache.length + 1)


def param_group_shapes(cfg: ArchConfig):
    n_rec, n_attn = _counts(cfg)
    D, R = _dims(cfg)
    F, H, KV, hd, V = cfg.d_ff, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.vocab
    g = {
        "rec/w_y": ((D, R), n_rec), "rec/w_x": ((D, R), n_rec),
        "rec/w_rg": ((R, R), n_rec), "rec/w_ig": ((R, R), n_rec),
        "rec/w_o": ((R, D), n_rec),
        "rec/mlp_wgate": ((D, F), n_rec), "rec/mlp_win": ((D, F), n_rec),
        "rec/mlp_wout": ((F, D), n_rec),
        "attn/wq": ((D, H * hd), n_attn), "attn/wk": ((D, KV * hd), n_attn),
        "attn/wv": ((D, KV * hd), n_attn), "attn/wo": ((H * hd, D), n_attn),
        "attn/mlp_wgate": ((D, F), n_attn), "attn/mlp_win": ((D, F), n_attn),
        "attn/mlp_wout": ((F, D), n_attn),
        "embed": ((V, D), 1),
    }
    if not cfg.tie_embeddings:
        g["head"] = ((D, V), 1)
    return g
