"""repro.models -- architecture zoo (pure JAX, scan-over-layers, pjit-ready).

Families: dense / moe / vlm (transformer.py), ssm (rwkv6.py),
hybrid (rglru.py), encdec (encdec.py).  See model.py for the unified API.
"""

from . import config, encdec, layers, model, moe, rglru, rwkv6, transformer
from .config import SHAPES, ArchConfig, InputShape
from .model import (
    count_params,
    decode_step,
    extra_inputs,
    forward,
    init_cache,
    init_params,
    loss_fn,
    param_group_shapes,
)

__all__ = [
    "config", "encdec", "layers", "model", "moe", "rglru", "rwkv6", "transformer",
    "SHAPES", "ArchConfig", "InputShape",
    "count_params", "decode_step", "extra_inputs", "forward",
    "init_cache", "init_params", "loss_fn", "param_group_shapes",
]
