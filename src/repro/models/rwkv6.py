"""RWKV-6 "Finch" -- attention-free RNN with data-dependent decay
[arXiv:2404.05892].

Per layer: a *time-mix* block (the WKV6 linear recurrence) and a
*channel-mix* block (token-shifted squared-ReLU FFN).

Time-mix recurrence per head (state S in R^{hd x hd}):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

with per-channel data-dependent decay ``w_t = exp(-exp(w0 + lora(x_t)))``
(kept in log-space for stability) and the "bonus" ``u`` for the current
token.  Token-shift mixing (DDLerp) interpolates each projection input
between x_t and x_{t-1} with a data-dependent coefficient.

Training-mode evaluation scans over time steps (state (B, H, hd, hd)); this
is the memory-light baseline.  The §Perf hillclimb evaluates a chunked
matmul formulation against it (see EXPERIMENTS.md).  Decode is a single
recurrence step -- O(1) in context length, which is why rwkv6 runs the
long_500k shape that full-attention archs skip.
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import layer_norm, rms_norm

Params = Dict[str, Any]

__all__ = [
    "init_params", "forward", "forward_hidden", "init_cache", "decode_step",
    "RWKVCache", "param_group_shapes", "time_mix_seq",
]

_MIX_NAMES = ("r", "k", "v", "w", "g")


class RWKVCache(NamedTuple):
    tm_x: jnp.ndarray      # (L, B, D) last input to time-mix
    cm_x: jnp.ndarray      # (L, B, D) last input to channel-mix
    S: jnp.ndarray         # (L, B, H, hd, hd) wkv state
    length: jnp.ndarray    # () int32


def _heads(cfg: ArchConfig) -> Tuple[int, int]:
    hd = cfg.rwkv_head_dim
    return cfg.d_model // hd, hd


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    D, F, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    H, hd = _heads(cfg)
    lora = cfg.time_decay_extra_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 20)
    s = 1.0 / math.sqrt(D)
    layers = {
        "ln1_w": jnp.ones((L, D), dt), "ln1_b": jnp.zeros((L, D), dt),
        "ln2_w": jnp.ones((L, D), dt), "ln2_b": jnp.zeros((L, D), dt),
        # token-shift DDLerp: base mix + per-stream mus + shared lora
        "mix_base": jnp.zeros((L, D), dt),
        "mix_mus": jnp.zeros((L, len(_MIX_NAMES), D), dt),
        "mix_w1": jax.random.normal(ks[0], (L, D, 32 * len(_MIX_NAMES)), dt) * s,
        "mix_w2": jax.random.normal(ks[1], (L, len(_MIX_NAMES), 32, D), dt) * 0.02,
        # time-mix projections
        "tm_wr": jax.random.normal(ks[2], (L, D, D), dt) * s,
        "tm_wk": jax.random.normal(ks[3], (L, D, D), dt) * s,
        "tm_wv": jax.random.normal(ks[4], (L, D, D), dt) * s,
        "tm_wg": jax.random.normal(ks[5], (L, D, D), dt) * s,
        "tm_wo": jax.random.normal(ks[6], (L, D, D), dt) * s,
        # data-dependent decay lora + base, and bonus u
        "decay_w0": jnp.full((L, D), -6.0, dt),
        "decay_w1": jax.random.normal(ks[7], (L, D, lora), dt) * s,
        "decay_w2": jax.random.normal(ks[8], (L, lora, D), dt) * 0.02,
        "bonus_u": jnp.zeros((L, H, hd), dt),
        # per-head group-norm of the wkv output
        "tm_ln_w": jnp.ones((L, D), dt), "tm_ln_b": jnp.zeros((L, D), dt),
        # channel-mix
        "cm_mix_k": jnp.zeros((L, D), dt),
        "cm_mix_r": jnp.zeros((L, D), dt),
        "cm_wk": jax.random.normal(ks[9], (L, D, F), dt) * s,
        "cm_wv": jax.random.normal(ks[10], (L, F, D), dt) * (1.0 / math.sqrt(F)),
        "cm_wr": jax.random.normal(ks[11], (L, D, D), dt) * s,
    }
    return {
        "embed": jax.random.normal(ks[12], (V, D), dt) * 0.02,
        "layers": layers,
        "ln_f_w": jnp.ones((D,), dt), "ln_f_b": jnp.zeros((D,), dt),
        "head": jax.random.normal(ks[13], (D, V), dt) * s,
    }


def _ddlerp(w: Params, x: jnp.ndarray, x_prev: jnp.ndarray):
    """Data-dependent token-shift: returns the 5 mixed inputs (r,k,v,w,g)."""
    sx = x_prev - x                                           # (B, T, D)
    base = x + sx * w["mix_base"]
    lora = jnp.tanh(base @ w["mix_w1"])                       # (B, T, 32*5)
    B, T = x.shape[0], x.shape[1]
    lora = lora.reshape(B, T, len(_MIX_NAMES), 32)
    dyn = jnp.einsum("btsi,sid->btsd", lora, w["mix_w2"])     # (B, T, 5, D)
    mus = w["mix_mus"][None, None]                            # (1, 1, 5, D)
    mixed = x[:, :, None, :] + sx[:, :, None, :] * (mus + dyn)
    return tuple(mixed[:, :, i, :] for i in range(len(_MIX_NAMES)))


def _decay_log(w: Params, xw: jnp.ndarray) -> jnp.ndarray:
    """log(w_t) = -exp(w0 + lora(xw)) in f32; always < 0."""
    lo = jnp.tanh(xw @ w["decay_w1"]) @ w["decay_w2"]
    return -jnp.exp((w["decay_w0"] + lo).astype(jnp.float32))


def time_mix_seq(
    cfg: ArchConfig, w: Params, x: jnp.ndarray, x_last: jnp.ndarray,
    S0: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """WKV6 over a sequence.  x: (B, T, D); S0: (B, H, hd, hd).
    Returns (out (B, T, D), x_tail (B, D), S_T)."""
    B, T, D = x.shape
    H, hd = _heads(cfg)
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(w, x, x_prev)

    r = (xr @ w["tm_wr"]).reshape(B, T, H, hd).astype(jnp.float32)
    k = (xk @ w["tm_wk"]).reshape(B, T, H, hd).astype(jnp.float32)
    v = (xv @ w["tm_wv"]).reshape(B, T, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ w["tm_wg"])
    logw = _decay_log(w, xw).reshape(B, T, H, hd)             # f32, < 0
    u = w["bonus_u"].astype(jnp.float32)                      # (H, hd)

    def step(S, rkvw):
        rt, kt, vt, lwt = rkvw                                # (B, H, hd)
        kv = kt[..., :, None] * vt[..., None, :]              # (B, H, hd, hd)
        ot = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = jnp.exp(lwt)[..., None] * S + kv
        return S, ot

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, logw))
    S_T, o = jax.lax.scan(step, S0.astype(jnp.float32), xs)
    o = o.transpose(1, 0, 2, 3).reshape(B, T, D)              # (B, T, D)

    o = layer_norm(o, w["tm_ln_w"], w["tm_ln_b"])             # per-channel GN
    o = (o * g).astype(x.dtype) @ w["tm_wo"]
    return o, x[:, -1, :], S_T.astype(S0.dtype)


def _channel_mix(w: Params, x: jnp.ndarray, x_last: jnp.ndarray):
    B, T, D = x.shape
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    xk = x + (x_prev - x) * w["cm_mix_k"]
    xr = x + (x_prev - x) * w["cm_mix_r"]
    kk = jnp.square(jax.nn.relu(xk @ w["cm_wk"]))
    return jax.nn.sigmoid(xr @ w["cm_wr"]) * (kk @ w["cm_wv"]), x[:, -1, :]


def _layer(cfg: ArchConfig, x, w, tm_x0, cm_x0, S0):
    h = layer_norm(x, w["ln1_w"], w["ln1_b"])
    o, tm_tail, S = time_mix_seq(cfg, w, h, tm_x0, S0)
    x = x + o
    h = layer_norm(x, w["ln2_w"], w["ln2_b"])
    o, cm_tail = _channel_mix(w, h, cm_x0)
    return x + o, tm_tail, cm_tail, S


def forward_hidden(cfg: ArchConfig, params: Params, tokens: jnp.ndarray, **_):
    dt = jnp.dtype(cfg.dtype)
    B, T = tokens.shape
    H, hd = _heads(cfg)
    x = params["embed"][tokens].astype(dt)
    zeros_x = jnp.zeros((B, cfg.d_model), dt)
    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def body(xc, w):
        out, _, _, _ = _layer(cfg, xc, w, zeros_x, zeros_x, S0)
        return out, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll)
    x = layer_norm(x, params["ln_f_w"], params["ln_f_b"])
    return x, params["head"]


def forward(cfg: ArchConfig, params: Params, tokens: jnp.ndarray, **_) -> jnp.ndarray:
    x, head = forward_hidden(cfg, params, tokens)
    return (x @ head).astype(jnp.float32)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, length=0) -> RWKVCache:
    dt = jnp.dtype(cfg.dtype)
    H, hd = _heads(cfg)
    L, D = cfg.n_layers, cfg.d_model
    return RWKVCache(
        tm_x=jnp.zeros((L, batch, D), dt),
        cm_x=jnp.zeros((L, batch, D), dt),
        S=jnp.zeros((L, batch, H, hd, hd), jnp.float32),
        length=jnp.asarray(length, jnp.int32),
    )


def decode_step(cfg: ArchConfig, params: Params, cache: RWKVCache,
                tokens: jnp.ndarray) -> Tuple[jnp.ndarray, RWKVCache]:
    dt = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    x = params["embed"][tokens].astype(dt)      # (B, 1, D)

    def body(xc, lw):
        w, tm_x0, cm_x0, S0 = lw
        out, tm, cm, S = _layer(cfg, xc, w, tm_x0, cm_x0, S0)
        return out, (tm, cm, S)

    x, (tm, cm, S) = jax.lax.scan(
        body, x, (params["layers"], cache.tm_x, cache.cm_x, cache.S)
    )
    x = layer_norm(x, params["ln_f_w"], params["ln_f_b"])
    logits = (x @ params["head"]).astype(jnp.float32)
    return logits, RWKVCache(tm_x=tm, cm_x=cm, S=S, length=cache.length + 1)


def param_group_shapes(cfg: ArchConfig):
    D, F, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    H, hd = _heads(cfg)
    lora = cfg.time_decay_extra_dim
    return {
        "layers/tm_wr": ((D, D), L), "layers/tm_wk": ((D, D), L),
        "layers/tm_wv": ((D, D), L), "layers/tm_wg": ((D, D), L),
        "layers/tm_wo": ((D, D), L),
        "layers/cm_wk": ((D, F), L), "layers/cm_wv": ((F, D), L),
        "layers/cm_wr": ((D, D), L),
        "layers/decay_w1": ((D, lora), L), "layers/decay_w2": ((lora, D), L),
        "layers/mix_w1": ((D, 32 * 5), L),
        "embed": ((V, D), 1), "head": ((D, V), 1),
        "layers/ln1_w": ((D,), L),
    }
