"""Decoder-only transformer stack (dense + MoE families).

Covers llama3-8b, tinyllama-1.1b, yi-34b (plain GQA), gemma3-1b (5:1
local:global sliding-window pattern), qwen2-vl-72b (M-RoPE + vision-prefix
stub), granite-moe / dbrx (MoE FFN via moe.py).

Layer parameters are *stacked* along a leading L axis and the stack is
iterated with ``lax.scan`` so the HLO stays compact for 40..80-layer configs
(see DESIGN.md Sec. 6 on how the roofline accounts for scan trip counts).

Per-layer heterogeneity (gemma3's local/global pattern) is carried as a
per-layer ``window`` array scanned alongside the weights -- the mask is
computed with dynamic window arithmetic so one traced body serves both kinds.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (
    KVCache,
    apply_mrope,
    apply_rope,
    attention,
    attention_gqa,
    decode_attention,
    decode_attention_gqa,
    repeat_kv,
    rms_norm,
    swiglu,
)
from .moe import init_moe_ffn, moe_ffn

Params = Dict[str, Any]

__all__ = [
    "init_params", "forward", "forward_hidden", "init_cache", "decode_step",
    "layer_fwd", "param_group_shapes",
]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _init_dense_layer(cfg: ArchConfig, key: jax.Array, L: int) -> Params:
    D, F, H, KV, hd = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 8)
    dt = _dtype(cfg)
    s = 1.0 / math.sqrt(D)
    sf = 1.0 / math.sqrt(F)
    p = {
        "ln_attn": jnp.zeros((L, D), dt),
        "ln_mlp": jnp.zeros((L, D), dt),
        "attn_wq": jax.random.normal(ks[0], (L, D, H * hd), dt) * s,
        "attn_wk": jax.random.normal(ks[1], (L, D, KV * hd), dt) * s,
        "attn_wv": jax.random.normal(ks[2], (L, D, KV * hd), dt) * s,
        "attn_wo": jax.random.normal(ks[3], (L, H * hd, D), dt) * (1.0 / math.sqrt(H * hd)),
    }
    if cfg.n_experts:
        p.update(init_moe_ffn(cfg, ks[4], L))
    else:
        p.update({
            "mlp_wgate": jax.random.normal(ks[5], (L, D, F), dt) * s,
            "mlp_win": jax.random.normal(ks[6], (L, D, F), dt) * s,
            "mlp_wout": jax.random.normal(ks[7], (L, F, D), dt) * sf,
        })
    return p


def padded_vocab(cfg: ArchConfig) -> int:
    """Megatron-style vocab padding so embed/head shard over "model"
    (SPerf switch; 0 = off)."""
    m = cfg.pad_vocab_multiple
    if not m:
        return cfg.vocab
    return ((cfg.vocab + m - 1) // m) * m


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    kE, kL, kH = jax.random.split(key, 3)
    dt = _dtype(cfg)
    D, V, L = cfg.d_model, padded_vocab(cfg), cfg.n_layers
    params: Params = {
        "embed": jax.random.normal(kE, (V, D), dt) * 0.02,
        "layers": _init_dense_layer(cfg, kL, L),
        "ln_f": jnp.zeros((D,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(kH, (D, V), dt) / math.sqrt(D)
    return params


def _layer_windows(cfg: ArchConfig) -> jnp.ndarray:
    """Per-layer sliding window (0 = full attention), from the pattern."""
    kinds = cfg.layer_kinds()
    return jnp.asarray(
        [cfg.sliding_window if k == "local" else 0 for k in kinds], jnp.int32
    )


def _positions_for(cfg: ArchConfig, tokens: jnp.ndarray, offset=0) -> jnp.ndarray:
    B, S = tokens.shape[0], tokens.shape[1]
    pos = offset + jnp.arange(S, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.pos_type == "mrope":
        return jnp.broadcast_to(pos[None], (3, B, S))   # text-only default
    return pos


def layer_fwd(
    cfg: ArchConfig,
    x: jnp.ndarray,                 # (B, S, D)
    w: Params,                      # one layer's params (no L axis)
    positions: jnp.ndarray,         # (B, S) or (3, B, S) for mrope
    window: jnp.ndarray,            # () int32, 0 = full
    q_chunk: int = 0,
) -> jnp.ndarray:
    """One transformer block (pre-norm attention + FFN/MoE)."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    h = rms_norm(x, w["ln_attn"], cfg.norm_eps)
    q = (h @ w["attn_wq"]).reshape(B, S, H, hd)
    k = (h @ w["attn_wk"]).reshape(B, S, KV, hd)
    v = (h @ w["attn_wv"]).reshape(B, S, KV, hd)
    if cfg.pos_type == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    elif cfg.pos_type == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.gqa_native and KV != H:
        o = attention_gqa(q, k, v, causal=True, window=window,
                          q_chunk=q_chunk, unroll=cfg.attn_unroll)
    else:
        o = attention(q, repeat_kv(k, H // KV), repeat_kv(v, H // KV),
                      causal=True, window=window, q_chunk=q_chunk,
                      unroll=cfg.attn_unroll)
    x = x + o.reshape(B, S, H * hd) @ w["attn_wo"]

    h = rms_norm(x, w["ln_mlp"], cfg.norm_eps)
    if cfg.n_experts:
        y = moe_ffn(cfg, h, w)
    else:
        y = swiglu(h, w["mlp_wgate"], w["mlp_win"], w["mlp_wout"])
    return x + y


def forward_hidden(
    cfg: ArchConfig,
    params: Params,
    tokens: jnp.ndarray,                       # (B, S) int32
    positions: Optional[jnp.ndarray] = None,
    vision_embeds: Optional[jnp.ndarray] = None,  # (B, P, D) stub prefix
    q_chunk: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Forward up to the final norm: (hidden (B, S_total, D), head (D, V))."""
    dt = _dtype(cfg)
    x = params["embed"][tokens].astype(dt)
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(dt), x], axis=1)
    B, S, D = x.shape
    if positions is None:
        positions = _positions_for(cfg, jnp.zeros((B, S)))
    qc = cfg.attn_chunk if q_chunk is None else q_chunk
    windows = _layer_windows(cfg)

    body_fn = lambda xc, lw: (
        layer_fwd(cfg, xc, lw[0], positions, lw[1], q_chunk=qc), None
    )
    if cfg.remat:
        body_fn = jax.checkpoint(
            body_fn, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = jax.lax.scan(body_fn, x, (params["layers"], windows),
                       unroll=cfg.scan_unroll)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return x, head


def forward(cfg: ArchConfig, params: Params, tokens: jnp.ndarray, **kw) -> jnp.ndarray:
    """Full logits (B, S_total, V) -- smoke/eval use; the training loss and
    the prefill step use forward_hidden to avoid materializing (B, S, V)."""
    x, head = forward_hidden(cfg, params, tokens, **kw)
    return (x @ head).astype(jnp.float32)[..., : cfg.vocab]


# --------------------------------------------------------------------------
# decode path
# --------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int, length=0) -> KVCache:
    """Stacked (L, B, S, KV, hd) KV cache; ``length`` marks pre-filled tokens
    (for dry-runs the cache content is abstract)."""
    dt = _dtype(cfg)
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return KVCache(
        k=jnp.zeros((L, batch, max_len, KV, hd), dt),
        v=jnp.zeros((L, batch, max_len, KV, hd), dt),
        length=jnp.asarray(length, jnp.int32),
    )


def decode_step(
    cfg: ArchConfig,
    params: Params,
    cache: KVCache,
    tokens: jnp.ndarray,           # (B, 1) int32
) -> Tuple[jnp.ndarray, KVCache]:
    """One new token against the KV cache; returns (logits (B, 1, V), cache)."""
    dt = _dtype(cfg)
    B = tokens.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    x = params["embed"][tokens].astype(dt)
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    pos = jnp.broadcast_to(cache.length[None, None], (B, 1))
    positions = jnp.broadcast_to(pos[None], (3, B, 1)) if cfg.pos_type == "mrope" else pos
    windows = _layer_windows(cfg)

    def body(carry, lw):
        x, = carry
        w, window, kc, vc = lw
        h = rms_norm(x, w["ln_attn"], cfg.norm_eps)
        q = (h @ w["attn_wq"]).reshape(B, 1, H, hd)
        k = (h @ w["attn_wk"]).reshape(B, 1, KV, hd)
        v = (h @ w["attn_wv"]).reshape(B, 1, KV, hd)
        if cfg.pos_type == "mrope":
            q = apply_mrope(q, positions, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.rope_theta)
        elif cfg.pos_type == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, cache.length, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, cache.length, 0, 0))
        if cfg.gqa_native and KV != H:
            o = decode_attention_gqa(q, kc, vc, cache.length + 1, window=window)
        else:
            o = decode_attention(
                q, repeat_kv(kc, H // KV), repeat_kv(vc, H // KV),
                cache.length + 1, window=window,
            )
        x = x + o.reshape(B, 1, H * hd) @ w["attn_wo"]
        h2 = rms_norm(x, w["ln_mlp"], cfg.norm_eps)
        if cfg.n_experts:
            y = moe_ffn(cfg, h2, w)
        else:
            y = swiglu(h2, w["mlp_wgate"], w["mlp_win"], w["mlp_wout"])
        return (x + y,), (kc, vc)

    (x,), (k_new, v_new) = jax.lax.scan(
        body, (x,), (params["layers"], windows, cache.k, cache.v)
    )
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (x @ head).astype(jnp.float32)[..., : cfg.vocab]
    return logits, KVCache(k=k_new, v=v_new, length=cache.length + 1)


# --------------------------------------------------------------------------
# compression-policy hook
# --------------------------------------------------------------------------

def param_group_shapes(cfg: ArchConfig) -> Dict[str, Tuple[Tuple[int, ...], int]]:
    """{group: (per-layer shape, stack)} for the GradESTC policy."""
    D, F, H, KV, hd, L = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.n_layers
    groups = {
        "layers/attn_wq": ((D, H * hd), L),
        "layers/attn_wk": ((D, KV * hd), L),
        "layers/attn_wv": ((D, KV * hd), L),
        "layers/attn_wo": ((H * hd, D), L),
        "layers/ln_attn": ((D,), L),
        "layers/ln_mlp": ((D,), L),
        "embed": ((padded_vocab(cfg), D), 1),
        "ln_f": ((D,), 1),
    }
    if cfg.n_experts:
        E = cfg.n_experts
        groups.update({
            "layers/moe_wgate": ((E, D, F), L),
            "layers/moe_win": ((E, D, F), L),
            "layers/moe_wout": ((E, F, D), L),
            "layers/router": ((D, E), L),
        })
    else:
        groups.update({
            "layers/mlp_wgate": ((D, F), L),
            "layers/mlp_win": ((D, F), L),
            "layers/mlp_wout": ((F, D), L),
        })
    if not cfg.tie_embeddings:
        groups["head"] = ((D, padded_vocab(cfg)), 1)
    return groups
